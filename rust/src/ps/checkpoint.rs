//! Durable, versioned server-state checkpoints (ISSUE 3).
//!
//! A checkpoint freezes everything the server needs to continue
//! Algorithm 1 from update `t`: θ^(t), the ADADELTA accumulators
//! (E[g²], E[Δ²] with their ρ/ε), and the per-worker clocks t_k of the
//! bounded-staleness gate.  Files are written next to their final path
//! and atomically renamed into place after an fsync, so a crash during
//! a save can never leave a half-written checkpoint where a resume
//! would find it; an FNV-1a checksum rejects files corrupted at rest.
//!
//! # Resume semantics
//!
//! Gradient *slots* are deliberately not persisted: a resumed server
//! re-enters Algorithm 1's "every live worker has pushed at least once"
//! precondition at the restored θ^(t), so the first post-resume update
//! aggregates only gradients computed at θ^(t) — never stale pre-crash
//! gradients.  The saved clocks travel for inspection and metrics; θ
//! and the optimizer state restore **bitwise** (f64 bit patterns are
//! stored verbatim), so the first θ a resumed run publishes is exactly
//! the checkpointed θ.
//!
//! Worker-side stream cursors (ISSUE 7): in-process workers record
//! `(initial offset, consumed windows)` into a shared registry before
//! every push, and the server snapshots the registry into each
//! checkpoint's cursor section.  A resumed coordinator hands each
//! worker its cursor back, so chunk-streaming workers replay *exactly*
//! the window schedule the uninterrupted run would have served — the
//! missing half of bitwise τ=0 streamed-store resume.  Networked
//! workers still re-seed from the stream head (their cursors live on
//! the far side of the wire — documented limitation).
//!
//! # File format `ADVGPCK1`
//!
//! All values little-endian:
//!
//! ```text
//! [ 0.. 8)  magic    b"ADVGPCK1"
//! [ 8..16)  version  u64 server iteration t
//! [16..32)  m, d     u64 × 2 (θ layout; dim is derived and checked)
//! [32..48)  ρ, ε     f64 × 2 ADADELTA hyperparameters
//! ...       θ        dim × f64
//! ...       E[g²]    dim × f64
//! ...       E[Δ²]    dim × f64
//! ...       workers  u64, then workers × (u8 tag, u64 t_k)
//! ...       cursors  u64 count, then count × (u64 worker, u64 offset,
//!           u64 windows), ascending by worker — OPTIONAL (ISSUE 7):
//!           pre-SH2 files end after the clocks; presence is inferred
//!           from the remaining length before the checksum, so both
//!           generations decode
//! ...       checksum u64 FNV-1a over everything above
//! ```

use super::sharded::{SliceSpec, Topology};
use crate::gp::ThetaLayout;
use crate::log_warn;
use crate::opt::AdaDelta;
use crate::util::json::Json;
use crate::util::{fnv1a64, FNV1A64_INIT};
use anyhow::{ensure, Context, Result};
use std::path::{Path, PathBuf};

/// Magic bytes opening every checkpoint file.
pub const CHECKPOINT_MAGIC: [u8; 8] = *b"ADVGPCK1";

/// File name of the sharded-checkpoint topology manifest (ISSUE 5):
/// written once at the root of a sharded checkpoint directory, it stamps
/// the slice layout the per-slice `slice_*/ck_*.bin` files were frozen
/// under, so a resume can validate the partition and reassemble θ
/// exactly.
pub const TOPOLOGY_MANIFEST: &str = "topology.json";

/// File name of the lineage manifest: one record per completed run
/// `(run_id, resumed_from, step, wall_time)`, appended at every seal and
/// surviving keep-last-K GC (the GC touches only `ck_*.bin`).
pub const LINEAGE_MANIFEST: &str = "lineage.json";

/// A frozen server state — see the module docs for semantics.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Server iteration t the state was frozen at (θ = θ^(t)).
    pub version: u64,
    /// θ layout the state belongs to.
    pub m: usize,
    pub d: usize,
    pub theta: Vec<f64>,
    /// ADADELTA hyperparameters and accumulators.
    pub rho: f64,
    pub eps: f64,
    pub eg2: Vec<f64>,
    pub ed2: Vec<f64>,
    /// Per-worker freshest-push clocks at save time (`None` = never
    /// pushed or retired).  Informational on restore — see module docs.
    pub clocks: Vec<Option<u64>>,
    /// Per-worker stream cursors `(worker, initial offset, consumed
    /// windows)` at save time, ascending by worker (ISSUE 7).  Empty
    /// when the run had no cursor registry (memory sources, networked
    /// workers, pre-SH2 files).
    pub cursors: Vec<(u64, u64, u64)>,
}

impl Checkpoint {
    /// Freeze the server state.
    pub fn capture(
        layout: ThetaLayout,
        version: u64,
        theta: &[f64],
        adadelta: &AdaDelta,
        clocks: Vec<Option<u64>>,
        cursors: Vec<(u64, u64, u64)>,
    ) -> Self {
        assert_eq!(theta.len(), layout.len(), "θ does not match layout");
        let (rho, eps) = adadelta.params();
        let (eg2, ed2) = adadelta.state();
        assert_eq!(eg2.len(), layout.len(), "optimizer does not match layout");
        Self {
            version,
            m: layout.m,
            d: layout.d,
            theta: theta.to_vec(),
            rho,
            eps,
            eg2: eg2.to_vec(),
            ed2: ed2.to_vec(),
            clocks,
            cursors,
        }
    }

    /// Freeze a *slice* server's state (ISSUE 5): identical field
    /// order and byte grammar to [`Checkpoint::capture`], but the θ /
    /// accumulator vectors are `slice.len()` long instead of the full
    /// layout dimension.  The `(m, d)` header still names the full
    /// layout; the sharded directory's [`TOPOLOGY_MANIFEST`] is what
    /// tells a reader the expected vector length (see
    /// [`Checkpoint::decode_with_dim`]).
    pub fn capture_slice(
        layout: ThetaLayout,
        slice: &SliceSpec,
        version: u64,
        theta: &[f64],
        adadelta: &AdaDelta,
        clocks: Vec<Option<u64>>,
        cursors: Vec<(u64, u64, u64)>,
    ) -> Self {
        assert!(slice.range.end <= layout.len(), "slice does not fit the layout");
        assert_eq!(theta.len(), slice.len(), "θ does not match the slice");
        let (rho, eps) = adadelta.params();
        let (eg2, ed2) = adadelta.state();
        assert_eq!(eg2.len(), slice.len(), "optimizer does not match the slice");
        Self {
            version,
            m: layout.m,
            d: layout.d,
            theta: theta.to_vec(),
            rho,
            eps,
            eg2: eg2.to_vec(),
            ed2: ed2.to_vec(),
            clocks,
            cursors,
        }
    }

    /// Restrict a full checkpoint to a θ index range — the coordinator
    /// uses this to hand each slice server its share of a resumed state
    /// (the inverse of [`Checkpoint::assemble`]).
    pub fn slice_of(&self, range: std::ops::Range<usize>) -> Self {
        assert!(range.end <= self.theta.len(), "slice range outside the checkpoint");
        Self {
            version: self.version,
            m: self.m,
            d: self.d,
            theta: self.theta[range.clone()].to_vec(),
            rho: self.rho,
            eps: self.eps,
            eg2: self.eg2[range.clone()].to_vec(),
            ed2: self.ed2[range].to_vec(),
            clocks: self.clocks.clone(),
            cursors: self.cursors.clone(),
        }
    }

    /// Reassemble a full checkpoint from per-slice parts (in slice-id
    /// order).  Versions and ADADELTA hyperparameters must agree
    /// bitwise across the parts; θ and the accumulators concatenate —
    /// because every server-side quantity is element-wise, the result
    /// is byte-for-byte the checkpoint a single server would have
    /// written at the same version.  Worker clocks and stream cursors
    /// are taken from slice 0 (every slice observes the same membership
    /// stream and shares one cursor registry).
    pub fn assemble(topology: &Topology, parts: &[Checkpoint]) -> Result<Self> {
        ensure!(
            parts.len() == topology.n_slices(),
            "assemble: {} checkpoint parts for a {}-slice topology",
            parts.len(),
            topology.n_slices()
        );
        let first = &parts[0];
        let mut theta = Vec::with_capacity(topology.dim);
        let mut eg2 = Vec::with_capacity(topology.dim);
        let mut ed2 = Vec::with_capacity(topology.dim);
        for (i, (part, r)) in parts.iter().zip(&topology.ranges).enumerate() {
            ensure!(
                part.version == first.version,
                "assemble: slice {i} is at version {} but slice 0 is at {} — \
                 slices must seal at a common version to resume",
                part.version,
                first.version
            );
            ensure!(
                (part.m, part.d) == (first.m, first.d)
                    && part.rho.to_bits() == first.rho.to_bits()
                    && part.eps.to_bits() == first.eps.to_bits(),
                "assemble: slice {i} disagrees on layout or optimizer \
                 hyperparameters"
            );
            ensure!(
                part.theta.len() == r.end - r.start,
                "assemble: slice {i} holds {} coordinates but the topology \
                 assigns it [{}, {})",
                part.theta.len(),
                r.start,
                r.end
            );
            theta.extend_from_slice(&part.theta);
            eg2.extend_from_slice(&part.eg2);
            ed2.extend_from_slice(&part.ed2);
        }
        ensure!(
            theta.len() == ThetaLayout::new(first.m, first.d).len(),
            "assemble: topology dim {} does not match layout m={} d={}",
            theta.len(),
            first.m,
            first.d
        );
        Ok(Self {
            version: first.version,
            m: first.m,
            d: first.d,
            theta,
            rho: first.rho,
            eps: first.eps,
            eg2,
            ed2,
            clocks: first.clocks.clone(),
            cursors: first.cursors.clone(),
        })
    }

    /// The layout this checkpoint was taken under.
    pub fn layout(&self) -> ThetaLayout {
        ThetaLayout::new(self.m, self.d)
    }

    /// Rebuild the optimizer; its next step continues the checkpointed
    /// trajectory bitwise.
    pub fn restore_adadelta(&self) -> AdaDelta {
        AdaDelta::from_state(self.rho, self.eps, self.eg2.clone(), self.ed2.clone())
    }

    /// Serialize to the `ADVGPCK1` byte layout.
    pub fn encode(&self) -> Vec<u8> {
        let dim = self.theta.len();
        let mut b = Vec::with_capacity(48 + 24 * dim + 8 + 9 * self.clocks.len() + 8);
        b.extend_from_slice(&CHECKPOINT_MAGIC);
        b.extend_from_slice(&self.version.to_le_bytes());
        b.extend_from_slice(&(self.m as u64).to_le_bytes());
        b.extend_from_slice(&(self.d as u64).to_le_bytes());
        b.extend_from_slice(&self.rho.to_le_bytes());
        b.extend_from_slice(&self.eps.to_le_bytes());
        for v in self.theta.iter().chain(&self.eg2).chain(&self.ed2) {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b.extend_from_slice(&(self.clocks.len() as u64).to_le_bytes());
        for c in &self.clocks {
            match c {
                Some(tk) => {
                    b.push(1);
                    b.extend_from_slice(&tk.to_le_bytes());
                }
                None => {
                    b.push(0);
                    b.extend_from_slice(&0u64.to_le_bytes());
                }
            }
        }
        // Cursor section (ISSUE 7): always written, even when empty —
        // only *pre-cursor* files omit it (decode infers presence from
        // the remaining length).
        b.extend_from_slice(&(self.cursors.len() as u64).to_le_bytes());
        for (worker, off, windows) in &self.cursors {
            b.extend_from_slice(&worker.to_le_bytes());
            b.extend_from_slice(&off.to_le_bytes());
            b.extend_from_slice(&windows.to_le_bytes());
        }
        let sum = fnv1a64(FNV1A64_INIT, &b);
        b.extend_from_slice(&sum.to_le_bytes());
        b
    }

    /// Parse and validate the `ADVGPCK1` byte layout (a full-θ file:
    /// the vector length is derived from the `(m, d)` header).
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        Self::decode_with_dim(bytes, None)
    }

    /// [`Checkpoint::decode`] with an externally-supplied vector length
    /// — how per-slice files are read: the byte grammar is identical,
    /// but a slice file's vectors are `slice.len()` long, a length only
    /// the sharded directory's [`TOPOLOGY_MANIFEST`] knows.  `None`
    /// derives the length from `(m, d)` (the full-θ case).
    pub fn decode_with_dim(bytes: &[u8], expect_dim: Option<usize>) -> Result<Self> {
        let mut r = Cursor { b: bytes, i: 0 };
        ensure!(
            r.take(8)? == CHECKPOINT_MAGIC,
            "checkpoint: bad magic (want {CHECKPOINT_MAGIC:?})"
        );
        let version = r.u64()?;
        let m = r.u64()? as usize;
        let d = r.u64()? as usize;
        // Plausibility-gate m/d *before* deriving the layout length:
        // a corrupt header must surface as Err, not as a multiply
        // overflow panic on the way to the checksum that would have
        // caught it.
        ensure!(
            (1..=1 << 20).contains(&m) && (1..=1 << 20).contains(&d),
            "checkpoint: implausible layout m={m} d={d} — corrupt header"
        );
        let full = ThetaLayout::new(m, d).len();
        let dim = match expect_dim {
            None => full,
            Some(n) => {
                ensure!(
                    n <= full,
                    "checkpoint: expected slice of {n} coordinates exceeds the \
                     layout dimension {full}"
                );
                n
            }
        };
        let rho = r.f64()?;
        let eps = r.f64()?;
        let theta = r.f64_vec(dim)?;
        let eg2 = r.f64_vec(dim)?;
        let ed2 = r.f64_vec(dim)?;
        let workers = r.u64()? as usize;
        ensure!(workers <= 1 << 20, "checkpoint: implausible worker count {workers}");
        let mut clocks = Vec::with_capacity(workers);
        for _ in 0..workers {
            let tag = r.take(1)?[0];
            let tk = r.u64()?;
            clocks.push(match tag {
                0 => None,
                1 => Some(tk),
                t => anyhow::bail!("checkpoint: bad clock tag {t}"),
            });
        }
        // Optional cursor section (ISSUE 7): pre-cursor files go
        // straight to the checksum here (exactly 8 bytes left); newer
        // files always carry at least the u64 count.
        let mut cursors = Vec::new();
        if bytes.len() - r.i > 8 {
            let count = r.u64()? as usize;
            ensure!(count <= 1 << 20, "checkpoint: implausible cursor count {count}");
            cursors.reserve(count);
            let mut prev: Option<u64> = None;
            for _ in 0..count {
                let worker = r.u64()?;
                ensure!(
                    prev.map_or(true, |p| worker > p),
                    "checkpoint: cursor workers out of order"
                );
                prev = Some(worker);
                cursors.push((worker, r.u64()?, r.u64()?));
            }
        }
        let body_end = r.i;
        let stored = r.u64()?;
        ensure!(r.i == bytes.len(), "checkpoint: trailing bytes after checksum");
        let actual = fnv1a64(FNV1A64_INIT, &bytes[..body_end]);
        ensure!(
            stored == actual,
            "checkpoint: checksum mismatch (stored {stored:#018x}, \
             computed {actual:#018x}) — file is corrupt"
        );
        Ok(Self { version, m, d, theta, rho, eps, eg2, ed2, clocks, cursors })
    }

    /// Save into `dir` (created if missing) as `ck_{version:012}.bin`
    /// via [`crate::util::atomic_write`] (temp-file + fsync + atomic
    /// rename + parent-directory fsync, so both the bytes and the new
    /// directory entry survive a crash — ISSUE 6).  Returns the final
    /// path.
    pub fn save_in(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
        let path = dir.join(format!("ck_{:012}.bin", self.version));
        crate::util::atomic_write(&path, &self.encode())
            .with_context(|| format!("save checkpoint {}", path.display()))?;
        Ok(path)
    }

    /// Load and validate one checkpoint file.
    pub fn load(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read checkpoint {}", path.display()))?;
        Self::decode(&bytes).with_context(|| format!("decode {}", path.display()))
    }

    /// Load a per-slice checkpoint file (vector length from the
    /// topology, not the header — see [`Checkpoint::decode_with_dim`]).
    pub fn load_slice(path: &Path, expect_dim: usize) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read slice checkpoint {}", path.display()))?;
        Self::decode_with_dim(&bytes, Some(expect_dim))
            .with_context(|| format!("decode {}", path.display()))
    }

    /// The version a checkpoint file name encodes (`ck_{v:012}.bin`).
    pub fn version_of_path(path: &Path) -> Option<u64> {
        let name = path.file_name()?.to_str()?;
        name.strip_prefix("ck_")?.strip_suffix(".bin")?.parse().ok()
    }

    /// All checkpoint files in `dir`, sorted oldest → newest.
    /// (Zero-padded fixed-width names sort lexically by version.)
    pub fn list_in(dir: &Path) -> Result<Vec<PathBuf>> {
        let mut files = Vec::new();
        if !dir.is_dir() {
            return Ok(files);
        }
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let name = match path.file_name().and_then(|n| n.to_str()) {
                Some(n) => n,
                None => continue,
            };
            if name.starts_with("ck_") && name.ends_with(".bin") {
                files.push(path);
            }
        }
        files.sort();
        Ok(files)
    }

    /// Path of the newest checkpoint in `dir` (highest version), if any.
    pub fn latest_in(dir: &Path) -> Result<Option<PathBuf>> {
        Ok(Self::list_in(dir)?.pop())
    }

    /// Retention GC (ROADMAP "Checkpoint GC/retention"): delete all but
    /// the newest `keep` checkpoint files in `dir`, returning the paths
    /// removed.  `keep` is clamped to ≥ 1 so the latest seal — the file
    /// a resume needs — can never be collected.  The server calls this
    /// after every *successful* save when
    /// [`TrainConfig::keep_last`](super::TrainConfig::keep_last) is set;
    /// it is also safe to run by hand on a cold directory.
    pub fn prune_keep_last(dir: &Path, keep: usize) -> Result<Vec<PathBuf>> {
        let keep = keep.max(1);
        let mut files = Self::list_in(dir)?;
        let cut = files.len().saturating_sub(keep);
        let removed: Vec<PathBuf> = files.drain(..cut).collect();
        for path in &removed {
            std::fs::remove_file(path)
                .with_context(|| format!("prune checkpoint {}", path.display()))?;
        }
        Ok(removed)
    }

    /// Load the newest **readable** checkpoint in `dir`, if any.
    ///
    /// Skip-on-corrupt (ISSUE 6): a newest file that fails to load —
    /// checksum mismatch, truncation mid-save on a crashed host,
    /// unreadable bytes — is logged and skipped, and the next-newest is
    /// tried, so one bad file never strands an otherwise resumable
    /// directory (keep-last-K retention guarantees older seals exist).
    /// Only when *every* checkpoint file fails does the error surface;
    /// an empty directory is still `Ok(None)`.
    pub fn load_latest(dir: &Path) -> Result<Option<Self>> {
        let mut newest_skipped = false;
        let mut last_err: Option<anyhow::Error> = None;
        for path in Self::list_in(dir)?.into_iter().rev() {
            match Self::load(&path) {
                Ok(ck) => {
                    if newest_skipped {
                        log_warn!(
                            "checkpoint: resuming from older {} — newer file(s) \
                             in the directory were corrupt",
                            path.display()
                        );
                    }
                    return Ok(Some(ck));
                }
                Err(e) => {
                    log_warn!(
                        "checkpoint: skipping unreadable {}: {e:#} — falling \
                         back to the next-newest file",
                        path.display()
                    );
                    newest_skipped = true;
                    last_err = Some(e);
                }
            }
        }
        match last_err {
            Some(e) => {
                Err(e.context("every checkpoint file in the directory failed to load"))
            }
            None => Ok(None),
        }
    }

    // ---- sharded checkpoint directories (ISSUE 5) ----

    /// The subdirectory of a sharded checkpoint root that slice `i` of
    /// `s` writes into.  Zero-padded so listings sort by slice id.
    pub fn slice_dir(root: &Path, i: usize, s: usize) -> PathBuf {
        root.join(format!("slice_{i:02}_of_{s:02}"))
    }

    /// Write the topology manifest at the root of a sharded checkpoint
    /// directory (idempotent: re-writing the same topology is fine; a
    /// *different* — or unreadable — existing manifest is a
    /// [`TopologyConflict`] error: re-partitioning a checkpoint
    /// directory in place would orphan the per-slice files, and
    /// checkpointing under a manifest that cannot describe the files is
    /// the same stale-resume hazard).  Callers distinguish the conflict
    /// (a configuration error, loud) from plain IO failures (best-effort
    /// durability, warn) by downcasting.
    pub fn save_topology(root: &Path, layout: ThetaLayout, topology: &Topology) -> Result<()> {
        ensure!(
            topology.dim == layout.len(),
            "topology dim {} does not match layout m={} d={}",
            topology.dim,
            layout.m,
            layout.d
        );
        match Self::load_topology(root) {
            Ok(Some((m, d, existing))) => {
                if (m, d) == (layout.m, layout.d) && existing == *topology {
                    return Ok(());
                }
                return Err(anyhow::Error::new(TopologyConflict(format!(
                    "checkpoint dir {} already holds a different topology \
                     ({} slices over m={m} d={d}) — delete it to re-partition",
                    root.display(),
                    existing.n_slices()
                ))));
            }
            Ok(None) => {}
            Err(e) => {
                return Err(anyhow::Error::new(TopologyConflict(format!(
                    "unreadable topology manifest in {}: {e:#} — refusing to \
                     checkpoint a partition the manifest cannot describe",
                    root.display()
                ))));
            }
        }
        std::fs::create_dir_all(root)
            .with_context(|| format!("create checkpoint dir {}", root.display()))?;
        let ranges = Json::Arr(
            topology
                .ranges
                .iter()
                .map(|r| Json::Arr(vec![Json::Num(r.start as f64), Json::Num(r.end as f64)]))
                .collect(),
        );
        let doc = Json::obj(vec![
            ("format", Json::Str("advgp-sharded-ck-v1".into())),
            ("m", Json::Num(layout.m as f64)),
            ("d", Json::Num(layout.d as f64)),
            ("dim", Json::Num(topology.dim as f64)),
            ("n_slices", Json::Num(topology.n_slices() as f64)),
            ("ranges", ranges),
        ]);
        crate::util::atomic_write(&root.join(TOPOLOGY_MANIFEST), doc.to_string().as_bytes())
            .with_context(|| format!("write {}/{}", root.display(), TOPOLOGY_MANIFEST))
    }

    /// Read the topology manifest of a sharded checkpoint directory:
    /// `Ok(None)` when the directory is not sharded (no manifest).
    pub fn load_topology(root: &Path) -> Result<Option<(usize, usize, Topology)>> {
        let path = root.join(TOPOLOGY_MANIFEST);
        if !path.is_file() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        ensure!(
            doc.get("format").and_then(Json::as_str) == Some("advgp-sharded-ck-v1"),
            "{}: unknown manifest format",
            path.display()
        );
        let field = |k: &str| {
            doc.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("{}: missing field {k}", path.display()))
        };
        let (m, d, dim, n) = (field("m")?, field("d")?, field("dim")?, field("n_slices")?);
        let pairs: Vec<(u64, u64)> = doc
            .get("ranges")
            .and_then(Json::as_arr)
            .context("manifest: missing ranges")?
            .iter()
            .map(|r| -> Result<(u64, u64)> {
                let a = r.as_arr().context("manifest: range is not a pair")?;
                ensure!(a.len() == 2, "manifest: range is not a pair");
                Ok((
                    a[0].as_usize().context("range start")? as u64,
                    a[1].as_usize().context("range end")? as u64,
                ))
            })
            .collect::<Result<_>>()?;
        ensure!(pairs.len() == n, "manifest: n_slices disagrees with ranges");
        let topology = Topology::from_wire(dim, &pairs)?;
        ensure!(
            ThetaLayout::new(m, d).len() == dim,
            "manifest: dim {dim} does not match layout m={m} d={d}"
        );
        Ok(Some((m, d, topology)))
    }

    /// Load the newest checkpoint a sharded directory can reassemble:
    /// the highest version present in **every** slice subdirectory
    /// (slices killed mid-save may be one cadence apart; keep-last-K
    /// retention runs per slice, so a small window of common versions
    /// always survives a healthy run).  Returns the assembled full-θ
    /// checkpoint — byte-for-byte what a single server would have
    /// sealed at that version.
    pub fn load_latest_sharded(root: &Path) -> Result<Option<Self>> {
        let Some((_m, _d, topology)) = Self::load_topology(root)? else {
            return Ok(None);
        };
        let s = topology.n_slices();
        // Per-slice version sets, intersected.
        let mut common: Option<std::collections::BTreeSet<u64>> = None;
        for i in 0..s {
            let dir = Self::slice_dir(root, i, s);
            let versions: std::collections::BTreeSet<u64> = Self::list_in(&dir)?
                .iter()
                .filter_map(|p| Self::version_of_path(p))
                .collect();
            common = Some(match common {
                None => versions,
                Some(c) => c.intersection(&versions).copied().collect(),
            });
        }
        let candidates: Vec<u64> = common
            .map(|c| c.into_iter().rev().collect())
            .unwrap_or_default();
        // Skip-on-corrupt (ISSUE 6), per *version*: a reassembly is
        // all-or-nothing, so one corrupt slice file disqualifies that
        // whole version and the next-newest common version is tried.
        let mut last_err: Option<anyhow::Error> = None;
        for v in candidates {
            let parts: Result<Vec<Checkpoint>> = (0..s)
                .map(|i| {
                    let path = Self::slice_dir(root, i, s).join(format!("ck_{v:012}.bin"));
                    Self::load_slice(&path, topology.ranges[i].end - topology.ranges[i].start)
                })
                .collect();
            match parts {
                Ok(parts) => {
                    if last_err.is_some() {
                        log_warn!(
                            "checkpoint: reassembling older sharded version {v} \
                             in {} — newer version(s) had corrupt slice files",
                            root.display()
                        );
                    }
                    return Self::assemble(&topology, &parts).map(Some);
                }
                Err(e) => {
                    log_warn!(
                        "checkpoint: skipping sharded version {v} in {}: {e:#} \
                         — falling back to the next-newest common version",
                        root.display()
                    );
                    last_err = Some(e);
                }
            }
        }
        match last_err {
            Some(e) => Err(e.context(
                "every common sharded checkpoint version failed to reassemble",
            )),
            None => Ok(None),
        }
    }

    /// Load the newest resumable state from a checkpoint directory of
    /// either shape: sharded (a [`TOPOLOGY_MANIFEST`] plus per-slice
    /// subdirectories) or classic flat `ck_*.bin` files.  Because the
    /// assembled sharded state is bitwise the single-server state, a
    /// single-server run can resume a sharded directory and vice versa
    /// — and a directory that has hosted **both** (a sharded run, then
    /// an unsharded continuation writing flat files at the root, or the
    /// reverse) resumes from whichever shape sealed the *newest*
    /// version, never from a stale manifest's older state.
    pub fn load_latest_any(dir: &Path) -> Result<Option<Self>> {
        let flat = Self::load_latest(dir)?;
        let sharded = if dir.join(TOPOLOGY_MANIFEST).is_file() {
            Self::load_latest_sharded(dir)?
        } else {
            None
        };
        Ok(match (flat, sharded) {
            (Some(f), Some(s)) => Some(if s.version > f.version { s } else { f }),
            (f, s) => f.or(s),
        })
    }
}

/// The topology-manifest conflict error of [`Checkpoint::save_topology`]
/// — an existing manifest names a different (or undecipherable)
/// partition.  A configuration error, not an IO hiccup: coordinators
/// escalate it loudly instead of the warn-and-continue treatment plain
/// save failures get.
#[derive(Debug)]
pub struct TopologyConflict(pub String);

impl std::fmt::Display for TopologyConflict {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for TopologyConflict {}

/// One completed run's entry in the [`LINEAGE_MANIFEST`]: which run
/// wrote into this directory, what it resumed from, where it stopped,
/// and how long it ran.  `load_latest` callers print the chain of these
/// as provenance across resumes.
#[derive(Clone, Debug, PartialEq)]
pub struct LineageRecord {
    /// Opaque per-run id (the coordinator generates one per
    /// `TrainConfig`).
    pub run_id: String,
    /// Version of the checkpoint this run resumed from (`None` for a
    /// fresh run).
    pub resumed_from: Option<u64>,
    /// Final published version when the run sealed.
    pub step: u64,
    /// Wall-clock seconds the run trained for.
    pub wall_secs: f64,
}

impl LineageRecord {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("run_id", Json::Str(self.run_id.clone())),
            (
                "resumed_from",
                self.resumed_from.map_or(Json::Null, |v| Json::Num(v as f64)),
            ),
            ("step", Json::Num(self.step as f64)),
            ("wall_secs", Json::Num(self.wall_secs)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            run_id: j
                .get("run_id")
                .and_then(Json::as_str)
                .context("lineage record: missing run_id")?
                .to_string(),
            resumed_from: match j.get("resumed_from") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_usize().context("lineage record: resumed_from")? as u64),
            },
            step: j
                .get("step")
                .and_then(Json::as_usize)
                .context("lineage record: missing step")? as u64,
            wall_secs: j
                .get("wall_secs")
                .and_then(Json::as_f64)
                .context("lineage record: missing wall_secs")?,
        })
    }
}

/// Read the lineage manifest of a checkpoint directory (empty when none
/// has been written yet).
pub fn read_lineage(dir: &Path) -> Result<Vec<LineageRecord>> {
    let path = dir.join(LINEAGE_MANIFEST);
    if !path.is_file() {
        return Ok(Vec::new());
    }
    let text =
        std::fs::read_to_string(&path).with_context(|| format!("read {}", path.display()))?;
    let doc =
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
    ensure!(
        doc.get("format").and_then(Json::as_str) == Some("advgp-lineage-v1"),
        "{}: unknown lineage format",
        path.display()
    );
    doc.get("records")
        .and_then(Json::as_arr)
        .context("lineage: missing records")?
        .iter()
        .map(LineageRecord::from_json)
        .collect()
}

/// Append one record to the lineage manifest (read-modify-write through
/// [`crate::util::atomic_write`], so a crash mid-append leaves the old
/// manifest intact).  Best-effort durability, same policy as checkpoint
/// saves: callers log and continue on error.  An *unreadable* existing
/// manifest (corruption, a future format revision) is an error, not an
/// empty history — overwriting it would silently destroy every prior
/// run's provenance.
pub fn append_lineage(dir: &Path, record: LineageRecord) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
    let mut records = read_lineage(dir)
        .context("existing lineage manifest is unreadable; refusing to overwrite it")?;
    records.push(record);
    let doc = Json::obj(vec![
        ("format", Json::Str("advgp-lineage-v1".into())),
        ("records", Json::Arr(records.iter().map(LineageRecord::to_json).collect())),
    ]);
    crate::util::atomic_write(&dir.join(LINEAGE_MANIFEST), doc.to_string().as_bytes())
        .with_context(|| format!("write {}/{}", dir.display(), LINEAGE_MANIFEST))
}

/// Human-readable provenance chain for a checkpoint directory — one
/// line per recorded run, oldest first.  Empty string when no lineage
/// has been recorded.
pub fn provenance(dir: &Path) -> Result<String> {
    let records = read_lineage(dir)?;
    let mut out = String::new();
    for r in &records {
        let from = match r.resumed_from {
            Some(v) => format!("resumed from v{v}"),
            None => "fresh".to_string(),
        };
        out.push_str(&format!(
            "run {} ({from}) -> sealed v{} after {:.1}s\n",
            r.run_id, r.step, r.wall_secs
        ));
    }
    Ok(out)
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, len: usize) -> Result<&'a [u8]> {
        ensure!(self.i + len <= self.b.len(), "checkpoint: truncated at byte {}", self.i);
        let s = &self.b[self.i..self.i + len];
        self.i += len;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64_vec(&mut self, len: usize) -> Result<Vec<f64>> {
        let raw = self.take(len * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use std::path::PathBuf;

    fn tdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("advgp_ck_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample(version: u64, seed: u64) -> Checkpoint {
        let layout = ThetaLayout::new(3, 2);
        let dim = layout.len();
        let mut rng = Pcg64::seeded(seed);
        let theta: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let mut ada = AdaDelta::default_for(dim);
        for _ in 0..5 {
            let g: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
            ada.step(&g);
        }
        Checkpoint::capture(
            layout,
            version,
            &theta,
            &ada,
            vec![Some(7), None, Some(9)],
            vec![(0, 3, version), (2, 11, version)],
        )
    }

    #[test]
    fn encode_decode_roundtrip_bitwise() {
        let ck = sample(42, 1);
        let back = Checkpoint::decode(&ck.encode()).unwrap();
        assert_eq!(back.version, 42);
        assert_eq!((back.m, back.d), (3, 2));
        assert_eq!(back.clocks, vec![Some(7), None, Some(9)]);
        assert_eq!(back.cursors, vec![(0, 3, 42), (2, 11, 42)]);
        for (a, b) in ck.theta.iter().zip(&back.theta) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in ck.eg2.iter().zip(&back.eg2).chain(ck.ed2.iter().zip(&back.ed2)) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(ck, back);
    }

    #[test]
    fn save_load_and_latest() {
        let dir = tdir("latest");
        for v in [3u64, 12, 7] {
            sample(v, v).save_in(&dir).unwrap();
        }
        let latest = Checkpoint::latest_in(&dir).unwrap().unwrap();
        assert!(latest.to_string_lossy().ends_with("ck_000000000012.bin"));
        let ck = Checkpoint::load_latest(&dir).unwrap().unwrap();
        assert_eq!(ck.version, 12);
        assert_eq!(ck, sample(12, 12));
        // Re-saving the same version overwrites atomically.
        sample(12, 99).save_in(&dir).unwrap();
        assert_eq!(Checkpoint::load_latest(&dir).unwrap().unwrap(), sample(12, 99));
        // Empty / missing dir.
        assert!(Checkpoint::load_latest(&tdir("empty")).unwrap().is_none());
        assert!(
            Checkpoint::load_latest(&PathBuf::from("/nonexistent/advgp"))
                .unwrap()
                .is_none()
        );
    }

    /// Keep-last-K GC removes exactly the oldest files, never the
    /// newest seal, and clamps degenerate `keep` values.
    #[test]
    fn prune_keeps_newest_k() {
        let dir = tdir("prune");
        for v in [5u64, 10, 15, 20, 25] {
            sample(v, v).save_in(&dir).unwrap();
        }
        // Non-checkpoint files are never touched.
        std::fs::write(dir.join("notes.txt"), b"keep me").unwrap();
        let removed = Checkpoint::prune_keep_last(&dir, 2).unwrap();
        assert_eq!(removed.len(), 3);
        let left = Checkpoint::list_in(&dir).unwrap();
        let versions: Vec<u64> =
            left.iter().map(|p| Checkpoint::load(p).unwrap().version).collect();
        assert_eq!(versions, vec![20, 25], "newest two survive");
        assert!(dir.join("notes.txt").is_file());
        // keep = 0 clamps to 1: the latest seal always survives.
        let removed = Checkpoint::prune_keep_last(&dir, 0).unwrap();
        assert_eq!(removed.len(), 1);
        assert_eq!(
            Checkpoint::load_latest(&dir).unwrap().unwrap().version,
            25,
            "seal survives a keep=0 prune"
        );
        // Nothing over-retained, nothing to do: no-op.
        assert!(Checkpoint::prune_keep_last(&dir, 4).unwrap().is_empty());
        // Empty / missing dir: no-op, not an error.
        assert!(Checkpoint::prune_keep_last(&tdir("prune_empty"), 3).unwrap().is_empty());
    }

    #[test]
    fn corruption_is_rejected() {
        let ck = sample(5, 2);
        let mut bytes = ck.encode();
        // Flip one payload byte: checksum must catch it.
        bytes[60] ^= 0x01;
        assert!(Checkpoint::decode(&bytes).is_err());
        // Truncation.
        let bytes = ck.encode();
        assert!(Checkpoint::decode(&bytes[..bytes.len() - 3]).is_err());
        // Bad magic.
        let mut bytes = ck.encode();
        bytes[0] ^= 0xFF;
        assert!(Checkpoint::decode(&bytes).is_err());
        // Corrupt m (header bytes 16..24): must be a clean Err, never a
        // multiply-overflow panic while deriving the layout length.
        let mut bytes = ck.encode();
        bytes[22] ^= 0xFF;
        assert!(Checkpoint::decode(&bytes).is_err());
        // m = 0 is as corrupt as m = huge.
        let mut bytes = ck.encode();
        bytes[16..24].copy_from_slice(&0u64.to_le_bytes());
        assert!(Checkpoint::decode(&bytes).is_err());
        // Trailing garbage.
        let mut bytes = ck.encode();
        bytes.push(0);
        assert!(Checkpoint::decode(&bytes).is_err());
    }

    /// A pre-cursor (PR 3 era) file — clocks, then checksum, no cursor
    /// section — still decodes, with empty cursors; and the cursor
    /// section's own validation rejects disorder.
    #[test]
    fn cursor_section_is_optional_and_validated() {
        let mut ck = sample(5, 3);
        ck.cursors.clear();
        // Rebuild the legacy byte stream: strip the (zero) cursor count
        // and the checksum, then re-checksum the shorter body.
        let new_bytes = ck.encode();
        let mut legacy = new_bytes[..new_bytes.len() - 16].to_vec();
        let sum = fnv1a64(FNV1A64_INIT, &legacy);
        legacy.extend_from_slice(&sum.to_le_bytes());
        let back = Checkpoint::decode(&legacy).unwrap();
        assert!(back.cursors.is_empty());
        assert_eq!(back, ck);
        // New-format empty-cursor files roundtrip too (the two byte
        // streams differ; both are valid).
        assert_eq!(Checkpoint::decode(&new_bytes).unwrap(), ck);
        assert_ne!(legacy, new_bytes);
        // Cursors must ascend strictly by worker id.
        let mut bad = sample(6, 4);
        bad.cursors = vec![(3, 1, 2), (1, 0, 2)];
        let err = Checkpoint::decode(&bad.encode()).unwrap_err();
        assert!(format!("{err:#}").contains("out of order"), "{err:#}");
    }

    #[test]
    fn restored_optimizer_continues_bitwise() {
        let layout = ThetaLayout::new(2, 1);
        let dim = layout.len();
        let mut ada = AdaDelta::default_for(dim);
        let g: Vec<f64> = (0..dim).map(|i| 0.3 * (i as f64 + 1.0)).collect();
        for _ in 0..8 {
            ada.step(&g);
        }
        let ck = Checkpoint::capture(layout, 8, &vec![0.0; dim], &ada, vec![], vec![]);
        let mut restored = ck.restore_adadelta();
        let da = ada.step(&g);
        let db = restored.step(&g);
        for (a, b) in da.iter().zip(&db) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// slice_of → assemble is the identity, bitwise, for any partition;
    /// slice files roundtrip through the byte grammar with an external
    /// length.
    #[test]
    fn slice_roundtrip_assembles_bitwise() {
        use crate::ps::sharded::Topology;
        let full = sample(21, 4);
        let dim = full.theta.len();
        for s in [1, 2, 3] {
            let topo = Topology::partition(dim, s);
            let parts: Vec<Checkpoint> = topo
                .ranges
                .iter()
                .map(|r| {
                    let part = full.slice_of(r.clone());
                    // Byte-grammar roundtrip with the external length.
                    let back =
                        Checkpoint::decode_with_dim(&part.encode(), Some(r.end - r.start))
                            .unwrap();
                    assert_eq!(back, part);
                    // A full-length decode of a slice file must fail
                    // loudly, never mis-slice.
                    if r.end - r.start != dim {
                        assert!(Checkpoint::decode(&part.encode()).is_err());
                    }
                    back
                })
                .collect();
            let assembled = Checkpoint::assemble(&topo, &parts).unwrap();
            assert_eq!(assembled.version, full.version);
            for (a, b) in full
                .theta
                .iter()
                .zip(&assembled.theta)
                .chain(full.eg2.iter().zip(&assembled.eg2))
                .chain(full.ed2.iter().zip(&assembled.ed2))
            {
                assert_eq!(a.to_bits(), b.to_bits(), "S={s}");
            }
        }
        // Version skew across parts is rejected.
        let topo = Topology::partition(dim, 2);
        let mut parts =
            vec![full.slice_of(topo.ranges[0].clone()), full.slice_of(topo.ranges[1].clone())];
        parts[1].version += 1;
        assert!(Checkpoint::assemble(&topo, &parts).is_err());
    }

    /// The topology manifest roundtrips, is idempotent, and refuses a
    /// re-partition in place.
    #[test]
    fn topology_manifest_roundtrip_and_conflict() {
        use crate::ps::sharded::Topology;
        let dir = tdir("topology");
        let layout = ThetaLayout::new(3, 2);
        let topo = Topology::partition(layout.len(), 2);
        assert!(Checkpoint::load_topology(&dir).unwrap().is_none());
        Checkpoint::save_topology(&dir, layout, &topo).unwrap();
        // Idempotent re-save.
        Checkpoint::save_topology(&dir, layout, &topo).unwrap();
        let (m, d, back) = Checkpoint::load_topology(&dir).unwrap().unwrap();
        assert_eq!((m, d), (3, 2));
        assert_eq!(back, topo);
        // A different partition over the same directory is an error.
        let other = Topology::partition(layout.len(), 3);
        assert!(Checkpoint::save_topology(&dir, layout, &other).is_err());
    }
}
