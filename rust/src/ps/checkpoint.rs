//! Durable, versioned server-state checkpoints (ISSUE 3).
//!
//! A checkpoint freezes everything the server needs to continue
//! Algorithm 1 from update `t`: θ^(t), the ADADELTA accumulators
//! (E[g²], E[Δ²] with their ρ/ε), and the per-worker clocks t_k of the
//! bounded-staleness gate.  Files are written next to their final path
//! and atomically renamed into place after an fsync, so a crash during
//! a save can never leave a half-written checkpoint where a resume
//! would find it; an FNV-1a checksum rejects files corrupted at rest.
//!
//! # Resume semantics
//!
//! Gradient *slots* are deliberately not persisted: a resumed server
//! re-enters Algorithm 1's "every live worker has pushed at least once"
//! precondition at the restored θ^(t), so the first post-resume update
//! aggregates only gradients computed at θ^(t) — never stale pre-crash
//! gradients.  The saved clocks travel for inspection and metrics; θ
//! and the optimizer state restore **bitwise** (f64 bit patterns are
//! stored verbatim), so the first θ a resumed run publishes is exactly
//! the checkpointed θ.  Worker-side stream cursors are *worker* state
//! and are not captured: chunk-streaming workers re-seed their
//! minibatch schedule on resume (see ROADMAP "Open items").
//!
//! # File format `ADVGPCK1`
//!
//! All values little-endian:
//!
//! ```text
//! [ 0.. 8)  magic    b"ADVGPCK1"
//! [ 8..16)  version  u64 server iteration t
//! [16..32)  m, d     u64 × 2 (θ layout; dim is derived and checked)
//! [32..48)  ρ, ε     f64 × 2 ADADELTA hyperparameters
//! ...       θ        dim × f64
//! ...       E[g²]    dim × f64
//! ...       E[Δ²]    dim × f64
//! ...       workers  u64, then workers × (u8 tag, u64 t_k)
//! ...       checksum u64 FNV-1a over everything above
//! ```

use crate::gp::ThetaLayout;
use crate::opt::AdaDelta;
use crate::util::{fnv1a64, FNV1A64_INIT};
use anyhow::{ensure, Context, Result};
use std::path::{Path, PathBuf};

/// Magic bytes opening every checkpoint file.
pub const CHECKPOINT_MAGIC: [u8; 8] = *b"ADVGPCK1";

/// A frozen server state — see the module docs for semantics.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Server iteration t the state was frozen at (θ = θ^(t)).
    pub version: u64,
    /// θ layout the state belongs to.
    pub m: usize,
    pub d: usize,
    pub theta: Vec<f64>,
    /// ADADELTA hyperparameters and accumulators.
    pub rho: f64,
    pub eps: f64,
    pub eg2: Vec<f64>,
    pub ed2: Vec<f64>,
    /// Per-worker freshest-push clocks at save time (`None` = never
    /// pushed or retired).  Informational on restore — see module docs.
    pub clocks: Vec<Option<u64>>,
}

impl Checkpoint {
    /// Freeze the server state.
    pub fn capture(
        layout: ThetaLayout,
        version: u64,
        theta: &[f64],
        adadelta: &AdaDelta,
        clocks: Vec<Option<u64>>,
    ) -> Self {
        assert_eq!(theta.len(), layout.len(), "θ does not match layout");
        let (rho, eps) = adadelta.params();
        let (eg2, ed2) = adadelta.state();
        assert_eq!(eg2.len(), layout.len(), "optimizer does not match layout");
        Self {
            version,
            m: layout.m,
            d: layout.d,
            theta: theta.to_vec(),
            rho,
            eps,
            eg2: eg2.to_vec(),
            ed2: ed2.to_vec(),
            clocks,
        }
    }

    /// The layout this checkpoint was taken under.
    pub fn layout(&self) -> ThetaLayout {
        ThetaLayout::new(self.m, self.d)
    }

    /// Rebuild the optimizer; its next step continues the checkpointed
    /// trajectory bitwise.
    pub fn restore_adadelta(&self) -> AdaDelta {
        AdaDelta::from_state(self.rho, self.eps, self.eg2.clone(), self.ed2.clone())
    }

    /// Serialize to the `ADVGPCK1` byte layout.
    pub fn encode(&self) -> Vec<u8> {
        let dim = self.theta.len();
        let mut b = Vec::with_capacity(48 + 24 * dim + 8 + 9 * self.clocks.len() + 8);
        b.extend_from_slice(&CHECKPOINT_MAGIC);
        b.extend_from_slice(&self.version.to_le_bytes());
        b.extend_from_slice(&(self.m as u64).to_le_bytes());
        b.extend_from_slice(&(self.d as u64).to_le_bytes());
        b.extend_from_slice(&self.rho.to_le_bytes());
        b.extend_from_slice(&self.eps.to_le_bytes());
        for v in self.theta.iter().chain(&self.eg2).chain(&self.ed2) {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b.extend_from_slice(&(self.clocks.len() as u64).to_le_bytes());
        for c in &self.clocks {
            match c {
                Some(tk) => {
                    b.push(1);
                    b.extend_from_slice(&tk.to_le_bytes());
                }
                None => {
                    b.push(0);
                    b.extend_from_slice(&0u64.to_le_bytes());
                }
            }
        }
        let sum = fnv1a64(FNV1A64_INIT, &b);
        b.extend_from_slice(&sum.to_le_bytes());
        b
    }

    /// Parse and validate the `ADVGPCK1` byte layout.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        let mut r = Cursor { b: bytes, i: 0 };
        ensure!(
            r.take(8)? == CHECKPOINT_MAGIC,
            "checkpoint: bad magic (want {CHECKPOINT_MAGIC:?})"
        );
        let version = r.u64()?;
        let m = r.u64()? as usize;
        let d = r.u64()? as usize;
        // Plausibility-gate m/d *before* deriving the layout length:
        // a corrupt header must surface as Err, not as a multiply
        // overflow panic on the way to the checksum that would have
        // caught it.
        ensure!(
            (1..=1 << 20).contains(&m) && (1..=1 << 20).contains(&d),
            "checkpoint: implausible layout m={m} d={d} — corrupt header"
        );
        let dim = ThetaLayout::new(m, d).len();
        let rho = r.f64()?;
        let eps = r.f64()?;
        let theta = r.f64_vec(dim)?;
        let eg2 = r.f64_vec(dim)?;
        let ed2 = r.f64_vec(dim)?;
        let workers = r.u64()? as usize;
        ensure!(workers <= 1 << 20, "checkpoint: implausible worker count {workers}");
        let mut clocks = Vec::with_capacity(workers);
        for _ in 0..workers {
            let tag = r.take(1)?[0];
            let tk = r.u64()?;
            clocks.push(match tag {
                0 => None,
                1 => Some(tk),
                t => anyhow::bail!("checkpoint: bad clock tag {t}"),
            });
        }
        let body_end = r.i;
        let stored = r.u64()?;
        ensure!(r.i == bytes.len(), "checkpoint: trailing bytes after checksum");
        let actual = fnv1a64(FNV1A64_INIT, &bytes[..body_end]);
        ensure!(
            stored == actual,
            "checkpoint: checksum mismatch (stored {stored:#018x}, \
             computed {actual:#018x}) — file is corrupt"
        );
        Ok(Self { version, m, d, theta, rho, eps, eg2, ed2, clocks })
    }

    /// Save into `dir` (created if missing) as `ck_{version:012}.bin`
    /// via [`crate::util::atomic_write`] (temp-file + fsync + atomic
    /// rename).  Returns the final path.
    pub fn save_in(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
        let path = dir.join(format!("ck_{:012}.bin", self.version));
        crate::util::atomic_write(&path, &self.encode())
            .with_context(|| format!("save checkpoint {}", path.display()))?;
        Ok(path)
    }

    /// Load and validate one checkpoint file.
    pub fn load(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read checkpoint {}", path.display()))?;
        Self::decode(&bytes).with_context(|| format!("decode {}", path.display()))
    }

    /// All checkpoint files in `dir`, sorted oldest → newest.
    /// (Zero-padded fixed-width names sort lexically by version.)
    pub fn list_in(dir: &Path) -> Result<Vec<PathBuf>> {
        let mut files = Vec::new();
        if !dir.is_dir() {
            return Ok(files);
        }
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let name = match path.file_name().and_then(|n| n.to_str()) {
                Some(n) => n,
                None => continue,
            };
            if name.starts_with("ck_") && name.ends_with(".bin") {
                files.push(path);
            }
        }
        files.sort();
        Ok(files)
    }

    /// Path of the newest checkpoint in `dir` (highest version), if any.
    pub fn latest_in(dir: &Path) -> Result<Option<PathBuf>> {
        Ok(Self::list_in(dir)?.pop())
    }

    /// Retention GC (ROADMAP "Checkpoint GC/retention"): delete all but
    /// the newest `keep` checkpoint files in `dir`, returning the paths
    /// removed.  `keep` is clamped to ≥ 1 so the latest seal — the file
    /// a resume needs — can never be collected.  The server calls this
    /// after every *successful* save when
    /// [`TrainConfig::keep_last`](super::TrainConfig::keep_last) is set;
    /// it is also safe to run by hand on a cold directory.
    pub fn prune_keep_last(dir: &Path, keep: usize) -> Result<Vec<PathBuf>> {
        let keep = keep.max(1);
        let mut files = Self::list_in(dir)?;
        let cut = files.len().saturating_sub(keep);
        let removed: Vec<PathBuf> = files.drain(..cut).collect();
        for path in &removed {
            std::fs::remove_file(path)
                .with_context(|| format!("prune checkpoint {}", path.display()))?;
        }
        Ok(removed)
    }

    /// Load the newest checkpoint in `dir`, if any.
    pub fn load_latest(dir: &Path) -> Result<Option<Self>> {
        match Self::latest_in(dir)? {
            Some(path) => Ok(Some(Self::load(&path)?)),
            None => Ok(None),
        }
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, len: usize) -> Result<&'a [u8]> {
        ensure!(self.i + len <= self.b.len(), "checkpoint: truncated at byte {}", self.i);
        let s = &self.b[self.i..self.i + len];
        self.i += len;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64_vec(&mut self, len: usize) -> Result<Vec<f64>> {
        let raw = self.take(len * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use std::path::PathBuf;

    fn tdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("advgp_ck_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample(version: u64, seed: u64) -> Checkpoint {
        let layout = ThetaLayout::new(3, 2);
        let dim = layout.len();
        let mut rng = Pcg64::seeded(seed);
        let theta: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let mut ada = AdaDelta::default_for(dim);
        for _ in 0..5 {
            let g: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
            ada.step(&g);
        }
        Checkpoint::capture(layout, version, &theta, &ada, vec![Some(7), None, Some(9)])
    }

    #[test]
    fn encode_decode_roundtrip_bitwise() {
        let ck = sample(42, 1);
        let back = Checkpoint::decode(&ck.encode()).unwrap();
        assert_eq!(back.version, 42);
        assert_eq!((back.m, back.d), (3, 2));
        assert_eq!(back.clocks, vec![Some(7), None, Some(9)]);
        for (a, b) in ck.theta.iter().zip(&back.theta) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in ck.eg2.iter().zip(&back.eg2).chain(ck.ed2.iter().zip(&back.ed2)) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(ck, back);
    }

    #[test]
    fn save_load_and_latest() {
        let dir = tdir("latest");
        for v in [3u64, 12, 7] {
            sample(v, v).save_in(&dir).unwrap();
        }
        let latest = Checkpoint::latest_in(&dir).unwrap().unwrap();
        assert!(latest.to_string_lossy().ends_with("ck_000000000012.bin"));
        let ck = Checkpoint::load_latest(&dir).unwrap().unwrap();
        assert_eq!(ck.version, 12);
        assert_eq!(ck, sample(12, 12));
        // Re-saving the same version overwrites atomically.
        sample(12, 99).save_in(&dir).unwrap();
        assert_eq!(Checkpoint::load_latest(&dir).unwrap().unwrap(), sample(12, 99));
        // Empty / missing dir.
        assert!(Checkpoint::load_latest(&tdir("empty")).unwrap().is_none());
        assert!(
            Checkpoint::load_latest(&PathBuf::from("/nonexistent/advgp"))
                .unwrap()
                .is_none()
        );
    }

    /// Keep-last-K GC removes exactly the oldest files, never the
    /// newest seal, and clamps degenerate `keep` values.
    #[test]
    fn prune_keeps_newest_k() {
        let dir = tdir("prune");
        for v in [5u64, 10, 15, 20, 25] {
            sample(v, v).save_in(&dir).unwrap();
        }
        // Non-checkpoint files are never touched.
        std::fs::write(dir.join("notes.txt"), b"keep me").unwrap();
        let removed = Checkpoint::prune_keep_last(&dir, 2).unwrap();
        assert_eq!(removed.len(), 3);
        let left = Checkpoint::list_in(&dir).unwrap();
        let versions: Vec<u64> =
            left.iter().map(|p| Checkpoint::load(p).unwrap().version).collect();
        assert_eq!(versions, vec![20, 25], "newest two survive");
        assert!(dir.join("notes.txt").is_file());
        // keep = 0 clamps to 1: the latest seal always survives.
        let removed = Checkpoint::prune_keep_last(&dir, 0).unwrap();
        assert_eq!(removed.len(), 1);
        assert_eq!(
            Checkpoint::load_latest(&dir).unwrap().unwrap().version,
            25,
            "seal survives a keep=0 prune"
        );
        // Nothing over-retained, nothing to do: no-op.
        assert!(Checkpoint::prune_keep_last(&dir, 4).unwrap().is_empty());
        // Empty / missing dir: no-op, not an error.
        assert!(Checkpoint::prune_keep_last(&tdir("prune_empty"), 3).unwrap().is_empty());
    }

    #[test]
    fn corruption_is_rejected() {
        let ck = sample(5, 2);
        let mut bytes = ck.encode();
        // Flip one payload byte: checksum must catch it.
        bytes[60] ^= 0x01;
        assert!(Checkpoint::decode(&bytes).is_err());
        // Truncation.
        let bytes = ck.encode();
        assert!(Checkpoint::decode(&bytes[..bytes.len() - 3]).is_err());
        // Bad magic.
        let mut bytes = ck.encode();
        bytes[0] ^= 0xFF;
        assert!(Checkpoint::decode(&bytes).is_err());
        // Corrupt m (header bytes 16..24): must be a clean Err, never a
        // multiply-overflow panic while deriving the layout length.
        let mut bytes = ck.encode();
        bytes[22] ^= 0xFF;
        assert!(Checkpoint::decode(&bytes).is_err());
        // m = 0 is as corrupt as m = huge.
        let mut bytes = ck.encode();
        bytes[16..24].copy_from_slice(&0u64.to_le_bytes());
        assert!(Checkpoint::decode(&bytes).is_err());
        // Trailing garbage.
        let mut bytes = ck.encode();
        bytes.push(0);
        assert!(Checkpoint::decode(&bytes).is_err());
    }

    #[test]
    fn restored_optimizer_continues_bitwise() {
        let layout = ThetaLayout::new(2, 1);
        let dim = layout.len();
        let mut ada = AdaDelta::default_for(dim);
        let g: Vec<f64> = (0..dim).map(|i| 0.3 * (i as f64 + 1.0)).collect();
        for _ in 0..8 {
            ada.step(&g);
        }
        let ck = Checkpoint::capture(layout, 8, &vec![0.0; dim], &ada, vec![]);
        let mut restored = ck.restore_adadelta();
        let da = ada.step(&g);
        let db = restored.step(&g);
        for (a, b) in da.iter().zip(&db) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
