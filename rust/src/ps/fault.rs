//! ADVGPFI1 — deterministic fault injection at the frame boundary
//! (ISSUE 6).
//!
//! A [`FaultPlan`] is a seeded, per-connection, per-direction schedule
//! of fault events keyed by frame index; a [`FaultProxy`] sits between
//! any worker/server socket pair and applies the plan reproducibly:
//! the same seed always yields the same plan, and re-running a chaos
//! test with the same plan replays the same fault sequence (pinned by
//! `rust/tests/chaos_ps.rs`).
//!
//! The proxy understands exactly one thing about the ADVGPNT1/2 wire
//! protocol: the 4-byte little-endian length prefix that delimits
//! frames (`docs/PROTOCOL.md`).  It never decodes bodies, so it is
//! transparent to the wire spec — every fault it injects is one the
//! real network could produce (loss, delay, bit rot, duplication, torn
//! writes, wedged peers, severed links).  Frame indices count per
//! connection and per direction, starting at 0 with the handshake
//! frame.
//!
//! The proxy is a *test harness*, not a production component: it lives
//! in the library (not `#[cfg(test)]`) so integration tests and future
//! soak binaries can drive it, but no training path constructs one.

use crate::log_debug;
use crate::util::rng::Pcg64;
use crate::util::Stopwatch;
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Which way a frame is travelling through the proxy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Direction {
    /// Worker → server (HELLO, PUSH/PUSH2, EXIT, PONG).
    ClientToServer,
    /// Server → worker (WELCOME/2, PUBLISH/2, PING, ERROR, SHUTDOWN).
    ServerToClient,
}

/// One injectable fault.  Every variant maps to a failure the real
/// network (or a real peer) can produce.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultEvent {
    /// Swallow the frame entirely (packet loss past the retransmit
    /// horizon — the stream stays framed, one message vanishes).
    Drop,
    /// Hold the frame for this many milliseconds before forwarding
    /// (congestion / a GC pause on a middlebox).
    DelayMs(u64),
    /// XOR one body byte (offset taken modulo the frame length) so the
    /// length prefix survives but the checksum cannot — the receiver
    /// must answer `ERROR` and drop the connection, never panic.
    CorruptByte(usize),
    /// Forward the frame twice (retransmit duplication); receivers
    /// must be idempotent to re-delivery.
    Duplicate,
    /// Forward only the first half of the frame, then sever both ways
    /// — a torn write, the classic crash-mid-send.
    TruncateMid,
    /// Stop forwarding in this direction forever while keeping the
    /// connection open (a wedged peer: alive at the TCP level, silent
    /// at the protocol level — what heartbeats exist to detect).
    Wedge,
    /// Shut the connection down both ways immediately (link cut).
    Sever,
}

/// One scheduled fault: apply `event` to frame number `frame` flowing
/// in `dir` on connection `conn` (by accept order; `None` = every
/// connection).  Recorded traces always carry a concrete `conn`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct FaultRule {
    pub conn: Option<usize>,
    pub dir: Direction,
    pub frame: u64,
    pub event: FaultEvent,
}

/// A deterministic fault schedule.  Build one explicitly from rules,
/// or draw one from a seed with [`FaultPlan::seeded`] — equality is
/// derived, so "same seed ⇒ same plan" is a plain `assert_eq!`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// A plan from explicit rules (sorted for stable comparison).
    pub fn new(mut rules: Vec<FaultRule>) -> Self {
        rules.sort();
        Self { rules }
    }

    /// Draw a plan from a seed: each requested event is assigned a
    /// uniformly random direction and a frame index in `frames`, via
    /// the repo's deterministic [`Pcg64`].  Same `(seed, events,
    /// frames)` ⇒ identical plan, on every platform, forever — this is
    /// what makes a chaos run replayable from its seed alone.
    pub fn seeded(seed: u64, events: &[FaultEvent], frames: Range<u64>) -> Self {
        assert!(frames.start < frames.end, "empty frame range");
        let mut rng = Pcg64::seeded(seed);
        let span = frames.end - frames.start;
        let rules = events
            .iter()
            .map(|&event| {
                let dir = if rng.next_below(2) == 0 {
                    Direction::ClientToServer
                } else {
                    Direction::ServerToClient
                };
                let frame = frames.start + rng.next_below(span);
                FaultRule { conn: None, dir, frame, event }
            })
            .collect();
        Self::new(rules)
    }

    /// The rules that apply to frame `frame` of connection `conn` in
    /// direction `dir`, in plan order.
    fn matching(&self, conn: usize, dir: Direction, frame: u64) -> Vec<FaultRule> {
        self.rules
            .iter()
            .filter(|r| {
                r.dir == dir && r.frame == frame && r.conn.map_or(true, |c| c == conn)
            })
            .copied()
            .collect()
    }
}

/// A fault-injecting TCP proxy: listens on an ephemeral loopback port,
/// and for every accepted connection opens its own connection to
/// `upstream` and pumps frames both ways, applying the plan.  Workers
/// connect to [`FaultProxy::addr`] instead of the server; neither end
/// can tell the proxy from a flaky network.
///
/// Applied faults are recorded (with the connection index made
/// concrete) and retrievable via [`FaultProxy::trace`] — the trace is
/// the replay witness chaos tests pin.
pub struct FaultProxy {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    trace: Arc<Mutex<Vec<FaultRule>>>,
    accept: Option<std::thread::JoinHandle<()>>,
}

/// Poll cadence for the nonblocking accept loop and the pump read
/// timeout — bounds shutdown latency without busy-spinning.
const POLL: Duration = Duration::from_millis(20);

impl FaultProxy {
    /// Start the proxy in front of `upstream` (e.g. a
    /// [`super::net::NetServer`] address).  Returns immediately; the
    /// accept loop and per-connection pumps run on background threads
    /// until [`FaultProxy::shutdown`] (or drop).
    pub fn start(upstream: &str, plan: FaultPlan) -> Result<Self> {
        let upstream: SocketAddr = upstream
            .parse()
            .with_context(|| format!("parse upstream address {upstream}"))?;
        let listener =
            TcpListener::bind("127.0.0.1:0").context("bind fault proxy listener")?;
        let addr = listener.local_addr().context("fault proxy local addr")?;
        listener.set_nonblocking(true).context("fault proxy nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let trace = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let stop = stop.clone();
            let trace = trace.clone();
            let plan = Arc::new(plan);
            std::thread::spawn(move || {
                let next_conn = AtomicUsize::new(0);
                while !stop.load(Ordering::Acquire) {
                    let client = match listener.accept() {
                        Ok((s, _)) => s,
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(POLL);
                            continue;
                        }
                        Err(_) => break,
                    };
                    let conn = next_conn.fetch_add(1, Ordering::Relaxed);
                    let server = match TcpStream::connect(upstream) {
                        Ok(s) => s,
                        Err(e) => {
                            // Upstream gone: refuse exactly as a dead
                            // server would — drop the client socket.
                            log_debug!("fault proxy: upstream connect failed: {e}");
                            continue;
                        }
                    };
                    let c2s = Direction::ClientToServer;
                    let s2c = Direction::ServerToClient;
                    spawn_pump(&client, &server, conn, c2s, &plan, &trace, &stop);
                    spawn_pump(&server, &client, conn, s2c, &plan, &trace, &stop);
                }
            })
        };
        Ok(Self { addr, stop, trace, accept: Some(accept) })
    }

    /// The address workers should connect to instead of the server.
    pub fn addr(&self) -> String {
        self.addr.to_string()
    }

    /// The faults actually applied so far, with concrete connection
    /// indices, sorted (pump threads race, so raw insertion order is
    /// not deterministic — the sorted multiset is).
    pub fn trace(&self) -> Vec<FaultRule> {
        let mut t = self.trace.lock().expect("fault trace poisoned").clone();
        t.sort();
        t
    }

    /// Stop accepting and wind down the pumps (each notices within one
    /// poll interval).  Established flows are severed by their pumps'
    /// stop checks, not here — in-flight frames may still land.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Clone the stream pair and spawn one pump direction on a detached
/// thread.  A racing close (clone failure) skips the pump: the other
/// direction's sever tears the flow down.
fn spawn_pump(
    from: &TcpStream,
    to: &TcpStream,
    conn: usize,
    dir: Direction,
    plan: &Arc<FaultPlan>,
    trace: &Arc<Mutex<Vec<FaultRule>>>,
    stop: &Arc<AtomicBool>,
) {
    let (Ok(from), Ok(to)) = (from.try_clone(), to.try_clone()) else { return };
    let (plan, trace, stop) = (plan.clone(), trace.clone(), stop.clone());
    std::thread::spawn(move || pump_dir(from, to, conn, dir, &plan, &trace, &stop));
}

/// Read exactly `buf.len()` bytes, treating read timeouts as polls of
/// the stop flag.  `Ok(false)` = EOF (clean or torn — the pump severs
/// either way) or stop; `Ok(true)` = buffer filled.
fn read_full(s: &mut TcpStream, buf: &mut [u8], stop: &AtomicBool) -> std::io::Result<bool> {
    let mut off = 0;
    while off < buf.len() {
        if stop.load(Ordering::Acquire) {
            return Ok(false);
        }
        match s.read(&mut buf[off..]) {
            Ok(0) => return Ok(false),
            Ok(k) => off += k,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                continue
            }
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// One direction of one proxied connection: parse length-prefixed
/// frames off `from`, apply the plan's matching rules, forward to
/// `to`.  Exits on EOF, a fatal socket error, a terminal fault
/// (Sever/TruncateMid), or proxy shutdown — always propagating the
/// close so neither real endpoint waits on a half-dead middlebox.
fn pump_dir(
    mut from: TcpStream,
    mut to: TcpStream,
    conn: usize,
    dir: Direction,
    plan: &FaultPlan,
    trace: &Mutex<Vec<FaultRule>>,
    stop: &AtomicBool,
) {
    let _ = from.set_read_timeout(Some(POLL));
    let mut frame: u64 = 0;
    let mut wedged = false;
    let mut buf: Vec<u8> = Vec::new();
    let sever = |from: &TcpStream, to: &TcpStream| {
        let _ = from.shutdown(Shutdown::Both);
        let _ = to.shutdown(Shutdown::Both);
    };
    loop {
        let mut len4 = [0u8; 4];
        match read_full(&mut from, &mut len4, stop) {
            Ok(true) => {}
            // EOF / stop: propagate the close downstream and finish.
            Ok(false) | Err(_) => return sever(&from, &to),
        }
        let len = u32::from_le_bytes(len4) as usize;
        // A prefix the receiver would reject anyway (the wire layer
        // enforces [9, MAX_FRAME_LEN]) means we lost framing: sever
        // rather than stream garbage forever.
        if !(9..=super::wire::MAX_FRAME_LEN).contains(&len) {
            return sever(&from, &to);
        }
        buf.resize(4 + len, 0);
        buf[..4].copy_from_slice(&len4);
        match read_full(&mut from, &mut buf[4..], stop) {
            Ok(true) => {}
            Ok(false) | Err(_) => return sever(&from, &to),
        }
        let rules = plan.matching(conn, dir, frame);
        frame += 1;
        let mut record = |r: FaultRule| {
            trace
                .lock()
                .expect("fault trace poisoned")
                .push(FaultRule { conn: Some(conn), ..r });
        };
        // Fold this frame's rules into one action set (rules compose:
        // e.g. Delay + Duplicate delays, then forwards twice).
        let mut dropped = false;
        let mut copies = 1usize;
        for r in rules {
            record(r);
            match r.event {
                FaultEvent::Drop => dropped = true,
                FaultEvent::DelayMs(ms) => sleep_unless_stopped(ms, stop),
                FaultEvent::CorruptByte(o) => buf[4 + o % len] ^= 0xFF,
                FaultEvent::Duplicate => copies += 1,
                FaultEvent::TruncateMid => {
                    let _ = to.write_all(&buf[..4 + len / 2]);
                    return sever(&from, &to);
                }
                FaultEvent::Wedge => wedged = true,
                FaultEvent::Sever => return sever(&from, &to),
            }
        }
        if wedged || dropped {
            // Keep draining so the sender never blocks on a full TCP
            // buffer — the peer sees protocol silence, not backpressure.
            continue;
        }
        for _ in 0..copies {
            if to.write_all(&buf).is_err() {
                return sever(&from, &to);
            }
        }
    }
}

// ---------------------------------------------------------------------
// ADVGPFI1 on disk (ISSUE 7): the same seeded-plan discipline, aimed at
// the ADVGPSH2 chunk store instead of the socket.  A [`StoreFaultPlan`]
// mutates specific chunk payloads of an on-disk store; every event maps
// to a failure real storage produces (bit rot, a scribbled block, a
// truncated file).  Deterministic end to end: same (seed, events,
// store) ⇒ same bytes flipped ⇒ same quarantine trace in the reader
// (pinned by `rust/tests/chaos_store.rs`).
// ---------------------------------------------------------------------

/// One injectable storage fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum StoreFaultEvent {
    /// XOR one stored payload byte (offset taken modulo the stored
    /// chunk length) — classic bit rot; the chunk checksum cannot
    /// survive it.
    CorruptByte(usize),
    /// Overwrite the whole stored payload with a 0xA5 scribble (a
    /// misdirected write landing on this block).  Never a no-op, unlike
    /// zero-fill on an already-zero payload.
    ScribbleChunk,
    /// Truncate the *file* in the middle of this chunk's payload — the
    /// chunk directory at the tail vanishes, so the shard stops opening
    /// at all (a torn download / lost tail extent).  A whole-shard
    /// fault, not a quarantinable one.
    TruncateAt,
}

/// One scheduled storage fault: apply `event` to chunk `chunk` of shard
/// file `file`.  Plans drawn from a seed may index past a short last
/// file; [`StoreFaultPlan::apply`] reduces indices modulo the actual
/// counts and the returned trace carries the concrete targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct StoreFaultRule {
    pub file: usize,
    pub chunk: usize,
    pub event: StoreFaultEvent,
}

/// A deterministic storage-fault schedule over a [`ShardSet`]'s files
/// (`crate::data::store`).  Equality is derived, so "same seed ⇒ same
/// plan" is a plain `assert_eq!`, mirroring [`FaultPlan`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StoreFaultPlan {
    pub rules: Vec<StoreFaultRule>,
}

impl StoreFaultPlan {
    /// A plan from explicit rules (sorted for stable comparison).
    pub fn new(mut rules: Vec<StoreFaultRule>) -> Self {
        rules.sort();
        Self { rules }
    }

    /// Draw a plan from a seed: each requested event is assigned a
    /// uniformly random file in `0..files` and chunk in `0..chunks`,
    /// via the repo's deterministic [`Pcg64`].  Same `(seed, events,
    /// files, chunks)` ⇒ identical plan, on every platform, forever.
    pub fn seeded(seed: u64, events: &[StoreFaultEvent], files: usize, chunks: usize) -> Self {
        assert!(files >= 1 && chunks >= 1, "empty fault target space");
        let mut rng = Pcg64::seeded(seed);
        let rules = events
            .iter()
            .map(|&event| StoreFaultRule {
                file: rng.next_below(files as u64) as usize,
                chunk: rng.next_below(chunks as u64) as usize,
                event,
            })
            .collect();
        Self::new(rules)
    }

    /// Apply every rule to the store at `dir`, mutating shard bytes on
    /// disk.  File/chunk indices are reduced modulo the actual counts;
    /// the returned trace carries the concrete `(file, chunk)` targets,
    /// sorted — the replay witness chaos tests pin.  Rules against a
    /// file an earlier `TruncateAt` already beheaded are skipped (its
    /// chunk directory is gone), keeping apply deterministic rather
    /// than erroring on its own handiwork.
    pub fn apply(&self, dir: &std::path::Path) -> Result<Vec<StoreFaultRule>> {
        use crate::data::store::{chunk_locations, ShardSet};
        let set = ShardSet::open(dir).context("open store for fault injection")?;
        let mut truncated = vec![false; set.r()];
        let mut applied = Vec::with_capacity(self.rules.len());
        for r in &self.rules {
            let file = r.file % set.r();
            if truncated[file] {
                continue;
            }
            let path = set.file_path(file);
            let locs =
                chunk_locations(path).context("locate chunks for fault injection")?;
            let chunk = r.chunk % locs.len();
            let (off, len) = locs[chunk];
            let mut bytes = std::fs::read(path)
                .with_context(|| format!("read shard {}", path.display()))?;
            match r.event {
                StoreFaultEvent::CorruptByte(o) => {
                    bytes[off as usize + o % len as usize] ^= 0xFF;
                }
                StoreFaultEvent::ScribbleChunk => {
                    bytes[off as usize..(off + len) as usize].fill(0xA5);
                }
                StoreFaultEvent::TruncateAt => {
                    bytes.truncate(off as usize + len as usize / 2);
                    truncated[file] = true;
                }
            }
            std::fs::write(path, &bytes)
                .with_context(|| format!("write faulted shard {}", path.display()))?;
            applied.push(StoreFaultRule { file, chunk, event: r.event });
        }
        applied.sort();
        Ok(applied)
    }
}

/// Sleep `ms`, polling the stop flag so shutdown is never gated on a
/// long injected delay.
fn sleep_unless_stopped(ms: u64, stop: &AtomicBool) {
    let sw = Stopwatch::start();
    while sw.millis() < ms as f64 {
        if stop.load(Ordering::Acquire) {
            return;
        }
        std::thread::sleep(POLL.min(Duration::from_millis(ms)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::wire::{self, Frame};

    /// Same seed ⇒ identical plan; every drawn frame index lands in
    /// the requested range; conn is unconstrained (`None`).
    #[test]
    fn seeded_plan_is_deterministic_and_in_range() {
        let events = [
            FaultEvent::Drop,
            FaultEvent::CorruptByte(13),
            FaultEvent::DelayMs(40),
            FaultEvent::Duplicate,
            FaultEvent::Sever,
        ];
        let a = FaultPlan::seeded(0xC0FFEE, &events, 3..17);
        let b = FaultPlan::seeded(0xC0FFEE, &events, 3..17);
        assert_eq!(a, b, "same seed must yield the same plan");
        assert_eq!(a.rules.len(), events.len());
        for r in &a.rules {
            assert!((3..17).contains(&r.frame), "frame {} out of range", r.frame);
            assert_eq!(r.conn, None);
        }
    }

    /// Spawn a one-shot echo server that reflects raw bytes.
    fn echo_server() -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            if let Ok((mut s, _)) = l.accept() {
                let mut buf = [0u8; 4096];
                while let Ok(k) = s.read(&mut buf) {
                    if k == 0 || s.write_all(&buf[..k]).is_err() {
                        break;
                    }
                }
            }
        });
        (addr, h)
    }

    /// A fault-free plan forwards frames untouched both ways.
    #[test]
    fn proxy_passes_frames_through() {
        let (addr, server) = echo_server();
        let mut proxy = FaultProxy::start(&addr.to_string(), FaultPlan::default()).unwrap();
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        wire::write_frame(&mut c, &Frame::Ping).unwrap();
        let mut scratch = Vec::new();
        let back = wire::read_frame(&mut c, &mut scratch).unwrap();
        assert!(matches!(back, Frame::Ping));
        assert!(proxy.trace().is_empty());
        drop(c);
        proxy.shutdown();
        let _ = server.join();
    }

    /// A Drop rule swallows exactly the indexed frame; later frames
    /// still flow, and the trace records the applied rule with a
    /// concrete connection index.
    #[test]
    fn proxy_drops_the_scheduled_frame() {
        let (addr, server) = echo_server();
        let plan = FaultPlan::new(vec![FaultRule {
            conn: Some(0),
            dir: Direction::ClientToServer,
            frame: 0,
            event: FaultEvent::Drop,
        }]);
        let mut proxy = FaultProxy::start(&addr.to_string(), plan).unwrap();
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        wire::write_frame(&mut c, &Frame::Ping).unwrap(); // frame 0: dropped
        wire::write_frame(&mut c, &Frame::Pong).unwrap(); // frame 1: passes
        let mut scratch = Vec::new();
        let back = wire::read_frame(&mut c, &mut scratch).unwrap();
        assert!(matches!(back, Frame::Pong), "dropped frame must not arrive");
        let trace = proxy.trace();
        assert_eq!(
            trace,
            vec![FaultRule {
                conn: Some(0),
                dir: Direction::ClientToServer,
                frame: 0,
                event: FaultEvent::Drop,
            }]
        );
        drop(c);
        proxy.shutdown();
        let _ = server.join();
    }

    /// A corrupted frame keeps its length prefix (framing survives)
    /// but fails the checksum at the receiver.
    #[test]
    fn corrupted_frame_fails_decode_downstream() {
        let (addr, server) = echo_server();
        let plan = FaultPlan::new(vec![FaultRule {
            conn: None,
            dir: Direction::ClientToServer,
            frame: 0,
            event: FaultEvent::CorruptByte(5),
        }]);
        let mut proxy = FaultProxy::start(&addr.to_string(), plan).unwrap();
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        wire::write_frame(&mut c, &Frame::Ping).unwrap();
        // The echo server reflects the corrupted bytes back at us; the
        // wire layer must reject them (checksum), not panic.
        let mut scratch = Vec::new();
        let err = wire::read_frame(&mut c, &mut scratch).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("checksum") || msg.contains("corrupt"),
            "unexpected error: {msg}"
        );
        assert_eq!(proxy.trace().len(), 1);
        drop(c);
        proxy.shutdown();
        let _ = server.join();
    }

    // -- StoreFaultPlan (disk) ----------------------------------------

    fn store_fixture(name: &str) -> (std::path::PathBuf, crate::data::Dataset) {
        let dir = std::env::temp_dir().join("advgp_fault_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ds = crate::data::synth::friedman(40, 3, 0.2, 11);
        // 2 files (20 + 20 rows), chunks of 6 → 4 + 4 = 8 chunks.
        crate::data::store::ShardSet::create(&dir, &ds, 2, 6).unwrap();
        (dir, ds)
    }

    /// Same seed ⇒ identical plan; drawn indices land in range.
    #[test]
    fn seeded_store_plan_is_deterministic_and_in_range() {
        let events = [
            StoreFaultEvent::CorruptByte(7),
            StoreFaultEvent::ScribbleChunk,
            StoreFaultEvent::CorruptByte(0),
            StoreFaultEvent::TruncateAt,
        ];
        let a = StoreFaultPlan::seeded(0xD15C_FA17, &events, 3, 9);
        let b = StoreFaultPlan::seeded(0xD15C_FA17, &events, 3, 9);
        assert_eq!(a, b, "same seed must yield the same plan");
        assert_eq!(a.rules.len(), events.len());
        for r in &a.rules {
            assert!(r.file < 3 && r.chunk < 9, "target out of range: {r:?}");
        }
        let c = StoreFaultPlan::seeded(0xD15C_FA18, &events, 3, 9);
        assert_ne!(a, c, "different seed should (here) differ");
    }

    /// `apply` flips exactly the planned chunk: that chunk fails its
    /// checksum at read time, every other chunk still verifies, and the
    /// returned trace names the concrete target.
    #[test]
    fn store_plan_apply_corrupts_the_planned_chunk_only() {
        use crate::data::store::ShardSet;
        let (dir, _ds) = store_fixture("apply_corrupt");
        let plan = StoreFaultPlan::new(vec![StoreFaultRule {
            file: 1,
            chunk: 2,
            event: StoreFaultEvent::CorruptByte(3),
        }]);
        let trace = plan.apply(&dir).unwrap();
        assert_eq!(trace, plan.rules);
        let set = ShardSet::open(&dir).unwrap();
        for file in 0..2 {
            let mut r = set.reader(file).unwrap();
            for c in 0..r.n_chunks() {
                let ok = r.verify_chunk(c).is_ok();
                assert_eq!(
                    ok,
                    !(file == 1 && c == 2),
                    "file {file} chunk {c}: wrong verify outcome"
                );
            }
        }
    }

    /// Out-of-range indices reduce modulo the actual counts, the trace
    /// reports the concrete targets, and applying the same plan to an
    /// identically rebuilt store yields the same trace.
    #[test]
    fn store_plan_apply_is_deterministic_and_wraps_indices() {
        let plan = StoreFaultPlan::seeded(
            0xABAD_D15C,
            &[StoreFaultEvent::ScribbleChunk, StoreFaultEvent::CorruptByte(100)],
            // Drawn over a larger space than the fixture (2 files × 4
            // chunks) to exercise the modulo reduction.
            5,
            50,
        );
        let (dir_a, _) = store_fixture("apply_replay_a");
        let (dir_b, _) = store_fixture("apply_replay_b");
        let ta = plan.apply(&dir_a).unwrap();
        let tb = plan.apply(&dir_b).unwrap();
        assert_eq!(ta, tb, "same plan + same store ⇒ same trace");
        for r in &ta {
            assert!(r.file < 2 && r.chunk < 4, "unreduced target: {r:?}");
        }
    }

    /// `TruncateAt` beheads the whole file — it stops opening (the
    /// chunk directory is gone) — and later rules against that file are
    /// skipped rather than erroring.
    #[test]
    fn store_plan_truncate_beheads_the_file() {
        use crate::data::store::ShardReader;
        let (dir, _ds) = store_fixture("apply_truncate");
        let plan = StoreFaultPlan::new(vec![
            StoreFaultRule { file: 0, chunk: 1, event: StoreFaultEvent::TruncateAt },
            StoreFaultRule { file: 0, chunk: 2, event: StoreFaultEvent::CorruptByte(0) },
        ]);
        let trace = plan.apply(&dir).unwrap();
        // Only the truncation lands; the follow-up rule is skipped.
        assert_eq!(
            trace,
            vec![StoreFaultRule { file: 0, chunk: 1, event: StoreFaultEvent::TruncateAt }]
        );
        assert!(ShardReader::open(&dir.join("shard_000.bin")).is_err());
        assert!(ShardReader::open(&dir.join("shard_001.bin")).is_ok());
    }
}
