//! Partitioned parameter server (ISSUE 5): θ sharded into `S` disjoint
//! contiguous slices, each owned by an independent server loop.
//!
//! ADVGP's weight-space augmentation makes the global update
//! **element-wise separable**: the ADADELTA direction and the proximal
//! projection (eqs. 18–20) touch each coordinate of θ independently, so
//! a server owning only `θ[a..b)` can run Algorithm 1's server side on
//! its slice with no cross-slice communication at all.  This module
//! holds the pieces every sharded topology (in-process threads,
//! loopback TCP, real multi-process deployments) shares:
//!
//! * [`SliceSpec`] / [`Topology`] — the partition itself: which slice
//!   owns which contiguous index range, derived deterministically from
//!   `(dim, S)` so every participant computes the same map.
//! * [`ShardedPublished`] — the worker-facing **assembled view**: one
//!   [`Published`] per slice plus an assembler pump that concatenates
//!   slice snapshots into a full θ whose version is the **floor of the
//!   version vector** (`min_s v_s`).  `run_worker` consumes the
//!   assembled handle and never learns the topology existed.
//! * [`run_splitter`] — the worker-side push fan-out: one full-θ
//!   gradient in, `S` per-slice fragment pushes out (worker math — the
//!   engine, windowing, profiles — is reused unchanged).
//! * [`merge_outcomes`] — folds the `S` per-slice [`ServerOutcome`]s
//!   back into one run report.
//!
//! # Version-vector staleness semantics
//!
//! Each slice server runs its own [`super::DelayGate`] and publishes its
//! own version counter, so at τ > 0 the slices drift: the assembled θ a
//! worker pulls may mix fragments from different slice versions.  That
//! is *by design* — coordinate-wise asynchrony is exactly the freedom
//! the element-wise separability buys (the same argument that lets
//! workers be stale lets slices be stale relative to each other).  The
//! assembled version is the vector floor, so a worker's push clock
//! `t_k` is a lower bound on every fragment's version, and each slice
//! gate still enforces `min_k t_k ≥ t_s − τ` for its own counter.  At
//! **τ = 0** the gates force lockstep: every slice advances only when
//! every worker has pushed at the current floor, all slices sit at the
//! same version, and the assembled trajectory is **bitwise identical**
//! to a single server's (pinned by `rust/tests/sharded_ps.rs`).

use super::messages::{Push, ToServer};
use super::metrics::ServerStats;
use super::server::ServerOutcome;
use super::Published;
use crate::log_warn;
use std::ops::Range;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// Most slices one run may be partitioned into.  WELCOME2 carries the
/// whole topology map inside a handshake frame (≤ 4096 bytes), and a
/// slice much smaller than θ's natural blocks stops being "highly
/// parallelizable" and starts being overhead; 64 server processes is
/// far beyond any realistic deployment of this system.
pub const MAX_SLICES: usize = 64;

/// One server's slice of θ: a contiguous index range plus its position
/// in the topology.  `SliceSpec::full` describes the classic
/// single-server run (slice 0 of 1, the whole vector).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SliceSpec {
    /// Which slice this is (`0..n_slices`).
    pub id: usize,
    /// Total slices in the topology.
    pub n_slices: usize,
    /// The contiguous global θ index range this slice owns.
    pub range: Range<usize>,
}

impl SliceSpec {
    /// The whole of θ as one slice — the single-server degenerate case.
    pub fn full(dim: usize) -> Self {
        Self { id: 0, n_slices: 1, range: 0..dim }
    }

    /// Coordinates in this slice.
    pub fn len(&self) -> usize {
        self.range.end - self.range.start
    }

    pub fn is_empty(&self) -> bool {
        self.range.is_empty()
    }

    /// Does this slice cover all of a `dim`-long θ?
    pub fn covers(&self, dim: usize) -> bool {
        self.range.start == 0 && self.range.end == dim
    }
}

/// The full partition map: `dim` coordinates tiled by `S` contiguous
/// ranges.  Derived deterministically from `(dim, S)` by
/// [`Topology::partition`], so the coordinator, every slice server, and
/// every worker agree on the layout without negotiation — the WELCOME2
/// topology map exists to *validate* that agreement, not to create it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    pub dim: usize,
    pub ranges: Vec<Range<usize>>,
}

impl Topology {
    /// Tile `0..dim` into `s` contiguous near-equal ranges: the first
    /// `dim % s` slices get `⌊dim/s⌋ + 1` coordinates, the rest
    /// `⌊dim/s⌋` — every slice non-empty for any `s ≤ dim` (a plain
    /// `div_ceil` chunking would leave trailing slices empty whenever
    /// `⌈dim/⌈dim/s⌉⌉ < s`, e.g. dim=100, s=64).  The same remainder
    /// scheme the coordinator uses to split thread budgets.
    pub fn partition(dim: usize, s: usize) -> Self {
        assert!(s >= 1, "need at least one slice");
        assert!(s <= MAX_SLICES, "{s} slices exceeds MAX_SLICES ({MAX_SLICES})");
        assert!(s <= dim, "cannot split {dim} coordinates into {s} non-empty slices");
        let base = dim / s;
        let extra = dim % s;
        let mut ranges = Vec::with_capacity(s);
        let mut start = 0;
        for i in 0..s {
            let len = base + usize::from(i < extra);
            ranges.push(start..start + len);
            start += len;
        }
        debug_assert_eq!(start, dim);
        Self { dim, ranges }
    }

    pub fn n_slices(&self) -> usize {
        self.ranges.len()
    }

    /// The [`SliceSpec`] for slice `i`.
    pub fn slice(&self, i: usize) -> SliceSpec {
        SliceSpec { id: i, n_slices: self.ranges.len(), range: self.ranges[i].clone() }
    }

    /// The wire form of the map (WELCOME2 payload): `(start, end)` per
    /// slice, in slice-id order.
    pub fn to_wire(&self) -> Vec<(u64, u64)> {
        self.ranges.iter().map(|r| (r.start as u64, r.end as u64)).collect()
    }

    /// Rebuild and validate a topology announced on the wire: ranges
    /// must be non-empty, contiguous, in order, and tile `0..dim`
    /// exactly.
    pub fn from_wire(dim: usize, pairs: &[(u64, u64)]) -> anyhow::Result<Self> {
        anyhow::ensure!(
            (1..=MAX_SLICES).contains(&pairs.len()),
            "topology with {} slices (max {MAX_SLICES})",
            pairs.len()
        );
        let mut ranges = Vec::with_capacity(pairs.len());
        let mut cursor = 0usize;
        for (i, &(a, b)) in pairs.iter().enumerate() {
            let (a, b) = (a as usize, b as usize);
            anyhow::ensure!(
                a == cursor && b > a && b <= dim,
                "topology slice {i} is [{a}, {b}) but the tiling cursor is at \
                 {cursor} (dim {dim}) — slices must tile θ contiguously"
            );
            cursor = b;
            ranges.push(a..b);
        }
        anyhow::ensure!(cursor == dim, "topology tiles only {cursor} of {dim} coordinates");
        Ok(Self { dim, ranges })
    }
}

/// The sharded twin of [`Published`]: one slice handle per server plus
/// the worker-facing assembled view.  The assembler pump
/// ([`run_assembler`]) keeps `assembled` at the version-vector floor of
/// the slices; workers, the evaluator, and the watchdog consume
/// `assembled` exactly as they would a single server's handle.
pub struct ShardedPublished {
    pub topology: Topology,
    pub slices: Vec<Arc<Published>>,
    pub assembled: Arc<Published>,
}

impl ShardedPublished {
    /// Seed every slice handle from `theta0` (version 0) and adopt the
    /// caller's `assembled` handle (which the caller has already seeded
    /// with the full θ₀ — e.g. via [`Published::new`]).
    pub fn new(topology: Topology, theta0: &[f64], assembled: Arc<Published>) -> Self {
        assert_eq!(theta0.len(), topology.dim, "θ₀ does not match the topology");
        let slices = topology
            .ranges
            .iter()
            .map(|r| Published::new(theta0[r.clone()].to_vec()))
            .collect();
        Self { topology, slices, assembled }
    }

    /// Republish a resumed state at `version` on every slice *and* the
    /// assembled view — the sharded twin of the coordinator's resume
    /// republish (the first θ anyone observes is the checkpointed θ).
    pub fn seed(&self, version: u64, theta: &[f64]) {
        assert_eq!(theta.len(), self.topology.dim);
        for (p, r) in self.slices.iter().zip(&self.topology.ranges) {
            p.publish(version, theta[r.clone()].to_vec());
        }
        self.assembled.publish(version, theta.to_vec());
    }

    /// The current per-slice versions (diagnostics; the assembled
    /// version is this vector's minimum).
    pub fn version_vector(&self) -> Vec<u64> {
        self.slices.iter().map(|p| p.snapshot().0).collect()
    }

    /// Signal shutdown on every handle (slices and assembled).
    pub fn shutdown_all(&self) {
        for p in &self.slices {
            p.shutdown();
        }
        self.assembled.shutdown();
    }
}

/// The assembler pump: block until **every** slice has a version newer
/// than the assembled floor, concatenate the fragments, publish the new
/// floor.  Exits (shutting the assembled view down) as soon as any
/// slice shuts down.  Run it on its own thread — scoped or detached —
/// for the life of the run.
///
/// At τ = 0 the floor advances one step at a time and every fragment is
/// at exactly the floor version, so the assembled θ is the single-server
/// θ bitwise; at τ > 0 fragments may be newer than the floor (the
/// documented version-vector semantics).
pub fn run_assembler(sharded: &ShardedPublished) {
    run_assembler_inner(sharded, Published::wait_newer_meta)
}

/// [`run_assembler`] with **draining** slice waits
/// ([`Published::wait_newer_draining`]): a slice's final publish is
/// assembled even when it races that slice's shutdown.  Workers use the
/// non-draining form (the last θ of a finished run buys them nothing);
/// the serving replica ([`crate::serve::replica`]) must use this one,
/// or its assembled view — and the posterior rebuilt from it — ends one
/// version behind the trainer, breaking ADVGPSV1's bitwise parity.
pub fn run_assembler_draining(sharded: &ShardedPublished) {
    run_assembler_inner(sharded, Published::wait_newer_draining)
}

fn run_assembler_inner(
    sharded: &ShardedPublished,
    wait: impl Fn(
        &Published,
        u64,
    ) -> Option<(u64, Arc<Vec<f64>>, super::messages::PublishMeta)>,
) {
    let topo = &sharded.topology;
    let mut seen = sharded.assembled.snapshot().0;
    loop {
        let mut floor = u64::MAX;
        let mut floor_meta = super::messages::PublishMeta::default();
        let mut parts: Vec<Arc<Vec<f64>>> = Vec::with_capacity(topo.n_slices());
        for p in &sharded.slices {
            match wait(p, seen) {
                Some((v, th, meta)) => {
                    if v < floor {
                        floor = v;
                        floor_meta = meta;
                    }
                    parts.push(th);
                }
                None => {
                    sharded.assembled.shutdown();
                    return;
                }
            }
        }
        let mut theta = vec![0.0f64; topo.dim];
        for (r, th) in topo.ranges.iter().zip(&parts) {
            debug_assert_eq!(th.len(), r.end - r.start);
            theta[r.clone()].copy_from_slice(th);
        }
        sharded.assembled.publish_meta(floor, theta, floor_meta);
        seen = floor;
    }
}

/// Split one worker message into its per-slice form: a [`Push`] becomes
/// one fragment push per slice (same worker/version/value/compute
/// metadata, the gradient restricted to the slice range); a
/// [`ToServer::WorkerExit`] fans out verbatim so every slice gate
/// retires the clock.
pub fn split_message(topology: &Topology, msg: &ToServer) -> Vec<ToServer> {
    match msg {
        ToServer::WorkerExit { worker } => topology
            .ranges
            .iter()
            .map(|_| ToServer::WorkerExit { worker: *worker })
            .collect(),
        ToServer::Push(p) => {
            assert_eq!(
                p.grad.len(),
                topology.dim,
                "worker {} pushed a {}-dim gradient into a {}-dim topology",
                p.worker,
                p.grad.len(),
                topology.dim
            );
            topology
                .ranges
                .iter()
                .map(|r| {
                    ToServer::Push(Push {
                        worker: p.worker,
                        version: p.version,
                        value: p.value,
                        grad: p.grad[r.clone()].to_vec(),
                        compute_secs: p.compute_secs,
                    })
                })
                .collect()
        }
    }
}

/// The splitter pump: drain the merged worker channel, fan each message
/// out to the per-slice server channels.  Exits when every worker-side
/// sender has dropped (which in turn drops the slice senders, letting
/// each slice server's receive loop observe disconnect).  Run on its
/// own thread for the life of the run.
pub fn run_splitter(
    topology: &Topology,
    rx: Receiver<ToServer>,
    slice_txs: Vec<Sender<ToServer>>,
) {
    assert_eq!(slice_txs.len(), topology.n_slices());
    while let Ok(msg) = rx.recv() {
        if let ToServer::Push(p) = &msg {
            if p.grad.len() != topology.dim {
                log_warn!(
                    "splitter: dropping worker {} push with dim {} (topology dim {})",
                    p.worker,
                    p.grad.len(),
                    topology.dim
                );
                continue;
            }
        }
        for (part, tx) in split_message(topology, &msg).into_iter().zip(&slice_txs) {
            if tx.send(part).is_err() {
                // That slice server already returned; keep feeding the
                // rest so their gates still see exits/pushes.
            }
        }
    }
}

/// Fold the `S` per-slice outcomes into one run report.
///
/// * `theta` — the concatenation of the slice θs (the final assembled
///   state; at τ=0 identical to a single server's final θ).
/// * `updates` — the version-vector floor (the assembled version).
/// * `pushes` — summed: each worker push lands once per slice, so this
///   counts slice-level messages (documented on [`ServerStats`]).
/// * `joins`/`leaves` — the max across slices: every slice observes the
///   same membership events, so the max is the event count (a sum would
///   multiply-count by `S`).
/// * `faults` — summed: transport faults are per-connection events and
///   each slice server owns disjoint connections (ISSUE 6).
/// * `store_quarantines` — summed: the coordinator hands the shared
///   quarantine counter to slice 0 only, so the sum *is* the session
///   count without double-tallying (ISSUE 7).
/// * timing/staleness series — taken from slice 0 (the slices see
///   statistically identical streams; merging reservoirs would not add
///   information).
pub fn merge_outcomes(topology: &Topology, outcomes: Vec<ServerOutcome>) -> ServerOutcome {
    assert_eq!(outcomes.len(), topology.n_slices());
    let mut theta = vec![0.0f64; topology.dim];
    for (r, o) in topology.ranges.iter().zip(&outcomes) {
        assert_eq!(o.theta.len(), r.end - r.start, "slice outcome length mismatch");
        theta[r.clone()].copy_from_slice(&o.theta);
    }
    let mut stats: ServerStats = outcomes[0].stats.clone();
    stats.updates = outcomes.iter().map(|o| o.stats.updates).min().unwrap_or(0);
    stats.pushes = outcomes.iter().map(|o| o.stats.pushes).sum();
    stats.joins = outcomes.iter().map(|o| o.stats.joins).max().unwrap_or(0);
    stats.leaves = outcomes.iter().map(|o| o.stats.leaves).max().unwrap_or(0);
    stats.faults = outcomes.iter().map(|o| o.stats.faults).sum();
    stats.store_quarantines = outcomes.iter().map(|o| o.stats.store_quarantines).sum();
    let last_value = outcomes[0].last_value;
    ServerOutcome { theta, stats, last_value }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::messages::PublishMeta;

    #[test]
    fn partition_tiles_exactly() {
        for (dim, s) in [(10, 1), (10, 3), (7, 7), (100, 64), (5, 2)] {
            let t = Topology::partition(dim, s);
            assert_eq!(t.n_slices(), s);
            let mut cursor = 0;
            for (i, r) in t.ranges.iter().enumerate() {
                assert_eq!(r.start, cursor, "slice {i} not contiguous");
                assert!(r.end > r.start, "slice {i} empty (dim {dim}, s {s})");
                cursor = r.end;
            }
            assert_eq!(cursor, dim);
            // The wire roundtrip reproduces the same map.
            let back = Topology::from_wire(dim, &t.to_wire()).unwrap();
            assert_eq!(back, t);
        }
    }

    #[test]
    #[should_panic(expected = "non-empty")]
    fn partition_rejects_more_slices_than_coordinates() {
        Topology::partition(3, 4);
    }

    #[test]
    fn from_wire_rejects_gaps_overlaps_and_short_tilings() {
        assert!(Topology::from_wire(10, &[(0, 4), (5, 10)]).is_err(), "gap");
        assert!(Topology::from_wire(10, &[(0, 6), (4, 10)]).is_err(), "overlap");
        assert!(Topology::from_wire(10, &[(0, 4)]).is_err(), "short");
        assert!(Topology::from_wire(10, &[(0, 4), (4, 4), (4, 10)]).is_err(), "empty slice");
        assert!(Topology::from_wire(10, &[]).is_err(), "no slices");
        assert!(Topology::from_wire(10, &[(0, 11)]).is_err(), "past dim");
    }

    #[test]
    fn split_message_fragments_and_fans_out() {
        let t = Topology::partition(5, 2); // [0..3), [3..5)
        let push = ToServer::Push(Push {
            worker: 7,
            version: 4,
            value: -2.5,
            grad: vec![1.0, 2.0, 3.0, 4.0, 5.0],
            compute_secs: 0.25,
        });
        let parts = split_message(&t, &push);
        assert_eq!(parts.len(), 2);
        match (&parts[0], &parts[1]) {
            (ToServer::Push(a), ToServer::Push(b)) => {
                assert_eq!(a.grad, vec![1.0, 2.0, 3.0]);
                assert_eq!(b.grad, vec![4.0, 5.0]);
                for p in [a, b] {
                    assert_eq!((p.worker, p.version, p.value, p.compute_secs), (7, 4, -2.5, 0.25));
                }
            }
            other => panic!("wrong split: {other:?}"),
        }
        let exits = split_message(&t, &ToServer::WorkerExit { worker: 7 });
        assert_eq!(exits, vec![
            ToServer::WorkerExit { worker: 7 },
            ToServer::WorkerExit { worker: 7 },
        ]);
    }

    /// The assembled view publishes the version-vector floor, mixing
    /// fragment versions when slices drift (τ > 0 semantics).
    #[test]
    fn assembler_publishes_the_version_floor() {
        let topo = Topology::partition(4, 2); // [0..2), [2..4)
        let assembled = Published::new(vec![0.0; 4]);
        let sharded = ShardedPublished::new(topo, &[0.0; 4], assembled.clone());
        let slices = sharded.slices.clone();
        let h = std::thread::spawn(move || run_assembler(&sharded));
        // Slice 0 races ahead to v2; slice 1 reaches v1: floor = 1.
        slices[0].publish_meta(1, vec![1.0, 1.0], PublishMeta { live: 2, staleness: 0 });
        slices[0].publish(2, vec![2.0, 2.0]);
        slices[1].publish(1, vec![10.0, 10.0]);
        let (v, th) = assembled.wait_newer(0).unwrap();
        assert_eq!(v, 1);
        // Fragments may be newer than the floor — slice 0's v2 payload
        // rides along (or its v1 did, if the assembler won the race);
        // either way slice 1's fragment is its v1 payload.
        assert_eq!(&th[2..4], &[10.0, 10.0]);
        assert!(th[0] == 1.0 || th[0] == 2.0);
        // Slice shutdown propagates to the assembled view and ends the
        // assembler.
        slices[0].shutdown();
        slices[1].shutdown();
        h.join().unwrap();
        assert!(assembled.snapshot().2, "assembled view must observe shutdown");
    }

    /// The draining assembler delivers a floor whose slices all
    /// published *before* shutting down — the publish+shutdown race the
    /// worker-side assembler deliberately loses (ADVGPSV1 parity).
    #[test]
    fn draining_assembler_assembles_the_final_racing_version() {
        let topo = Topology::partition(4, 2);
        let assembled = Published::new(vec![0.0; 4]);
        let sharded = ShardedPublished::new(topo, &[0.0; 4], assembled.clone());
        // The race, pre-staged: both slices publish v1 and shut down
        // before the assembler even starts.
        for (p, val) in sharded.slices.iter().zip([1.0, 2.0]) {
            p.publish(1, vec![val; 2]);
            p.shutdown();
        }
        run_assembler_draining(&sharded);
        let (v, th, sd) = assembled.snapshot();
        assert_eq!(v, 1, "final racing version must be assembled");
        assert_eq!(*th, vec![1.0, 1.0, 2.0, 2.0]);
        assert!(sd, "shutdown still propagates after the drain");
        // The non-draining assembler on the same pre-staged state drops
        // v1 (shutdown wins) — pinning why the replica needs draining.
        let assembled2 = Published::new(vec![0.0; 4]);
        let sharded2 = ShardedPublished::new(
            Topology::partition(4, 2),
            &[0.0; 4],
            assembled2.clone(),
        );
        for p in &sharded2.slices {
            p.publish(1, vec![5.0; 2]);
            p.shutdown();
        }
        run_assembler(&sharded2);
        assert_eq!(assembled2.snapshot().0, 0, "worker semantics drop the race");
    }

    #[test]
    fn merge_outcomes_concatenates_and_floors() {
        let topo = Topology::partition(4, 2);
        let mk = |theta: Vec<f64>, updates, pushes, joins, leaves| {
            let mut stats = ServerStats::default();
            stats.updates = updates;
            stats.pushes = pushes;
            stats.joins = joins;
            stats.leaves = leaves;
            ServerOutcome { theta, stats, last_value: -1.0 }
        };
        let mut a = mk(vec![1.0, 2.0], 10, 40, 1, 2);
        a.stats.store_quarantines = 3; // slice 0 holds the shared counter
        let merged = merge_outcomes(&topo, vec![a, mk(vec![3.0, 4.0], 9, 38, 1, 2)]);
        assert_eq!(merged.theta, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(merged.stats.updates, 9, "version-vector floor");
        assert_eq!(merged.stats.pushes, 78, "slice-level pushes sum");
        assert_eq!(merged.stats.joins, 1);
        assert_eq!(merged.stats.leaves, 2);
        assert_eq!(merged.stats.store_quarantines, 3, "summed, tallied once");
    }
}
