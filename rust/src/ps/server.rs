//! The server loop: delayed gradient aggregation + proximal updates
//! (Algorithm 1, server side).

use super::delay::DelayGate;
use super::messages::{Push, ToServer};
use super::metrics::ServerStats;
use super::Published;
use crate::gp::ThetaLayout;
use crate::opt::{prox_update, AdaDelta, StepSchedule};
use crate::util::Stopwatch;
use std::sync::mpsc::Receiver;
use std::sync::Arc;

pub struct ServerConfig {
    pub layout: ThetaLayout,
    pub workers: usize,
    pub tau: u64,
    /// Stop after this many server updates.
    pub max_updates: u64,
    /// Global learning-rate scale multiplying the ADADELTA direction.
    pub lr: f64,
    /// Proximal strength schedule γ_t (eqs. 18–20).
    pub prox: StepSchedule,
    /// Element-wise server shards for the update step (the paper's
    /// "highly parallelizable" server-side prox; 1 = single shard).
    pub server_shards: usize,
    /// If true, hyperparameters (Z, kernel, noise) are frozen and only
    /// the variational block is optimized (used by ablations/baselines).
    pub freeze_hyper: bool,
}

/// Outcome of the server loop.
pub struct ServerOutcome {
    pub theta: Vec<f64>,
    pub stats: ServerStats,
    /// Total data-term value at the last aggregation (diagnostics).
    pub last_value: f64,
}

/// Run the server until `max_updates` or all workers exit.
pub fn run_server(
    cfg: &ServerConfig,
    published: Arc<Published>,
    rx: Receiver<ToServer>,
) -> ServerOutcome {
    let layout = cfg.layout;
    let dim = layout.len();
    let mut theta = published.snapshot().1.as_ref().clone();
    assert_eq!(theta.len(), dim);
    let mut gate = DelayGate::new(cfg.workers, cfg.tau);
    // Freshest gradient per worker (the Σ_k ∇G_k^{(t_k)} aggregation
    // uses the latest push of each worker).
    let mut slots: Vec<Option<Push>> = (0..cfg.workers).map(|_| None).collect();
    let mut adadelta = AdaDelta::default_for(dim);
    let mut t: u64 = 0;
    let mut stats = ServerStats::default();
    let mut live_workers = cfg.workers;
    let clock = Stopwatch::start();
    let mut last_update = 0.0f64;
    let mut last_value = f64::NAN;

    while t < cfg.max_updates && live_workers > 0 {
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => break, // all senders dropped
        };
        match msg {
            ToServer::WorkerExit { worker: _ } => {
                live_workers -= 1;
                continue;
            }
            ToServer::Push(push) => {
                stats.pushes += 1;
                stats.worker_compute_secs.push(push.compute_secs);
                gate.record(push.worker, push.version);
                let w = push.worker;
                slots[w] = Some(push);
            }
        }

        // Drain any queued pushes before checking the gate — keeps the
        // aggregation as fresh as possible without blocking.
        while let Ok(msg) = rx.try_recv() {
            match msg {
                ToServer::WorkerExit { .. } => live_workers -= 1,
                ToServer::Push(push) => {
                    stats.pushes += 1;
                    stats.worker_compute_secs.push(push.compute_secs);
                    gate.record(push.worker, push.version);
                    let w = push.worker;
                    slots[w] = Some(push);
                }
            }
        }

        if !gate.permits(t) {
            continue;
        }

        // ---- Algorithm 1, server lines 2–5 ----
        if let Some(s) = gate.staleness(t) {
            stats.staleness.push(s as f64);
        }
        let mut grad = vec![0.0f64; dim];
        let mut value = 0.0f64;
        for slot in slots.iter().flatten() {
            for (g, s) in grad.iter_mut().zip(&slot.grad) {
                *g += s;
            }
            value += slot.value;
        }
        last_value = value;
        if cfg.freeze_hyper {
            for g in grad[layout.z_range().start..].iter_mut() {
                *g = 0.0;
            }
        }
        let gamma = cfg.prox.at(t);
        apply_update(
            &layout,
            &mut theta,
            &mut adadelta,
            &grad,
            cfg.lr,
            gamma,
            cfg.server_shards,
        );
        t += 1;
        published.publish(t, theta.clone());
        let now = clock.secs();
        stats.iter_secs.push(now - last_update);
        last_update = now;
        stats.updates = t;
    }

    published.shutdown();
    // Drain remaining messages so worker sends never block (they use an
    // unbounded channel, but be tidy and record exits).
    while let Ok(_msg) = rx.try_recv() {}
    ServerOutcome { theta, stats, last_value }
}

/// One server update: ADADELTA-scaled gradient step + prox projection,
/// optionally parallelized element-wise across `shards` threads — the
/// paper's "element-wise, closed-form … highly parallelizable" claim.
pub fn apply_update(
    layout: &ThetaLayout,
    theta: &mut [f64],
    adadelta: &mut AdaDelta,
    grad: &[f64],
    lr: f64,
    gamma: f64,
    shards: usize,
) {
    let delta = adadelta.step(grad);
    if shards <= 1 {
        for (t, d) in theta.iter_mut().zip(&delta) {
            *t += lr * d;
        }
        prox_update(layout, theta, gamma);
    } else {
        // Element-wise partition: every shard owns a contiguous slice of
        // θ, applies the gradient step and its slice of the prox without
        // any cross-shard communication.
        let dim = theta.len();
        let chunk = dim.div_ceil(shards);
        let layout = *layout;
        let scale = 1.0 / (1.0 + gamma);
        std::thread::scope(|scope| {
            for (si, (t_chunk, d_chunk)) in theta
                .chunks_mut(chunk)
                .zip(delta.chunks(chunk))
                .enumerate()
            {
                scope.spawn(move || {
                    let base = si * chunk;
                    for (off, (t, d)) in
                        t_chunk.iter_mut().zip(d_chunk).enumerate()
                    {
                        *t += lr * d;
                        let idx = base + off;
                        // Element-wise prox (eqs. 18–20).
                        if layout.is_variational(idx) {
                            if layout.is_u_diag(idx) {
                                let up = *t;
                                *t = (up
                                    + (up * up + 4.0 * (1.0 + gamma) * gamma)
                                        .sqrt())
                                    / (2.0 * (1.0 + gamma));
                            } else {
                                *t *= scale;
                            }
                        }
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn sharded_update_matches_serial() {
        let layout = ThetaLayout::new(6, 3);
        let dim = layout.len();
        let mut rng = Pcg64::seeded(3);
        let theta0: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let grad: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let mut serial = theta0.clone();
        let mut ada1 = AdaDelta::default_for(dim);
        apply_update(&layout, &mut serial, &mut ada1, &grad, 0.7, 0.3, 1);
        for shards in [2, 3, 5, 16] {
            let mut sharded = theta0.clone();
            let mut ada = AdaDelta::default_for(dim);
            apply_update(&layout, &mut sharded, &mut ada, &grad, 0.7, 0.3, shards);
            for (a, b) in serial.iter().zip(&sharded) {
                assert!((a - b).abs() < 1e-12, "shards={shards}");
            }
        }
    }
}
