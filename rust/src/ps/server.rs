//! The server loop: delayed gradient aggregation + proximal updates
//! (Algorithm 1, server side), with elastic membership and durable
//! checkpoints (ISSUE 3).

use super::checkpoint::Checkpoint;
use super::delay::DelayGate;
use super::messages::{Push, PublishMeta, ToServer, STALENESS_UNKNOWN};
use super::metrics::ServerStats;
use super::sharded::SliceSpec;
use super::Published;
use crate::gp::ThetaLayout;
use crate::opt::{prox_update, AdaDelta, StepSchedule};
use crate::{log_debug, log_warn};
use crate::util::Stopwatch;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

pub struct ServerConfig {
    pub layout: ThetaLayout,
    /// The contiguous θ slice this server owns (ISSUE 5 partitioning).
    /// [`SliceSpec::full`] for the classic single-server run; a proper
    /// sub-range when the coordinator shards θ across `S` server loops
    /// — the prox and ADADELTA are element-wise, so the loop below is
    /// identical either way, just restricted to the range.  The
    /// `published` handle, gradients, and checkpoints of a slice server
    /// are all slice-sized.
    pub slice: SliceSpec,
    pub workers: usize,
    pub tau: u64,
    /// Stop once the published version reaches this many updates.  On a
    /// resumed run the count continues from the checkpoint version, so
    /// this is a *cumulative* ceiling across resumes.
    pub max_updates: u64,
    /// Global learning-rate scale multiplying the ADADELTA direction.
    pub lr: f64,
    /// Proximal strength schedule γ_t (eqs. 18–20).
    pub prox: StepSchedule,
    /// Element-wise server shards for the update step (the paper's
    /// "highly parallelizable" server-side prox; 1 = single shard).
    pub server_shards: usize,
    /// If true, hyperparameters (Z, kernel, noise) are frozen and only
    /// the variational block is optimized (used by ablations/baselines).
    pub freeze_hyper: bool,
    /// Write a checkpoint every N updates (0 = never).  Cadence writes
    /// happen on a background thread so publishing never stalls on
    /// fsync (a hit is skipped if the previous save is still in
    /// flight); a final synchronous seal at the end of the run is
    /// always written when enabled.
    pub checkpoint_every: u64,
    /// Where checkpoints go (required when `checkpoint_every > 0`).
    pub checkpoint_dir: Option<PathBuf>,
    /// Checkpoint GC: after every successful save, keep only the
    /// newest K checkpoint files in the directory (`None` = keep all).
    /// Clamped to ≥ 1 — a run always retains its latest seal.
    pub keep_last: Option<usize>,
    /// Resume from this frozen state: θ, the version counter, and the
    /// ADADELTA accumulators restore bitwise; the gate starts fresh so
    /// every live worker must push once at the restored θ before the
    /// first post-resume update (see `ps::checkpoint` module docs).
    pub resume: Option<Checkpoint>,
    /// Late joiners the coordinator has declared but that may not have
    /// pushed yet.  The server keeps running while any are outstanding,
    /// so a run whose initial workers all depart before a declared
    /// joiner arrives waits for it instead of ending early.
    pub expected_joiners: usize,
    /// Transport-fault counter shared with this slice's accept loop
    /// (ISSUE 6; see [`super::net::NetServeOpts::faults`]): sampled
    /// into [`ServerStats::faults`] when the loop returns.  `None` for
    /// in-process runs — there is no transport to fault.
    pub transport_faults: Option<Arc<AtomicU64>>,
    /// Shared per-worker stream-cursor registry (ISSUE 7): when set,
    /// the server snapshots it immediately *before* every publish —
    /// the instant every worker is still blocked on `wait_newer`, so at
    /// τ=0 the snapshot is exact — and seals the snapshot into each
    /// checkpoint's cursor section.  `None` disables cursor capture
    /// (memory sources, networked workers).
    pub cursors: Option<super::worker::CursorRegistry>,
    /// Store-quarantine counter shared with every worker's
    /// [`QuarantinePolicy`](crate::data::store::QuarantinePolicy)
    /// (ISSUE 7): sampled into [`ServerStats::store_quarantines`] when
    /// the loop returns.  On sharded runs the coordinator hands it to
    /// slice 0 only, so the merge's sum counts each quarantine once.
    pub store_quarantines: Option<Arc<AtomicU64>>,
}

/// Outcome of the server loop.
pub struct ServerOutcome {
    pub theta: Vec<f64>,
    pub stats: ServerStats,
    /// Total data-term value at the last aggregation (diagnostics).
    pub last_value: f64,
}

/// Absorb one worker message into the gate / gradient slots / stats —
/// shared by the blocking receive and the opportunistic drain.
/// `joiner_pending[i]` tracks whether declared joiner id
/// `initial_workers + i` is still outstanding; only *that* id's first
/// admission clears its slot, so a retired member rejoining can never
/// consume a declared joiner's keep-alive.
fn absorb(
    msg: ToServer,
    gate: &mut DelayGate,
    slots: &mut Vec<Option<Push>>,
    stats: &mut ServerStats,
    initial_workers: usize,
    joiner_pending: &mut [bool],
) {
    match msg {
        ToServer::WorkerExit { worker } => {
            // Only a member's departure is a leave: an exit for an id
            // that never pushed and was never declared (an observer
            // connection, a failed handshake) must not inflate the
            // membership report.
            if !gate.is_retired(worker) {
                stats.leaves += 1;
            }
            gate.retire(worker);
            // Drop the departed worker's gradient: a retired worker
            // must stop contributing to Σ_k ∇G_k immediately.
            if worker < slots.len() {
                slots[worker] = None;
            }
        }
        ToServer::Push(push) => {
            let w = push.worker;
            if w >= slots.len() {
                slots.resize_with(w + 1, || None);
            }
            stats.pushes += 1;
            stats.worker_compute_secs.push(push.compute_secs);
            // The gate decides what counts as an admission (unknown or
            // retired id), so joins are counted correctly even when
            // joiners' first pushes arrive out of id order.
            if gate.record(w, push.version) {
                stats.joins += 1;
                if let Some(slot) = w
                    .checked_sub(initial_workers)
                    .and_then(|i| joiner_pending.get_mut(i))
                {
                    *slot = false;
                }
            }
            slots[w] = Some(push);
        }
    }
}

/// Freeze the server state and resolve the destination directory —
/// the shared front half of both checkpoint paths.  `None` (with a
/// warning) when no directory is configured.
fn capture_checkpoint(
    cfg: &ServerConfig,
    t: u64,
    theta: &[f64],
    adadelta: &AdaDelta,
    gate: &DelayGate,
    cursors: &[(u64, u64, u64)],
) -> Option<(Checkpoint, PathBuf)> {
    let Some(dir) = cfg.checkpoint_dir.clone() else {
        log_warn!("checkpoint_every set but no checkpoint_dir; skipping");
        return None;
    };
    Some((
        Checkpoint::capture_slice(
            cfg.layout,
            &cfg.slice,
            t,
            theta,
            adadelta,
            gate.clocks(),
            cursors.to_vec(),
        ),
        dir,
    ))
}

/// Save and swallow-with-warning: training outlives a failed save —
/// durability is best-effort, correctness of the run is not affected.
/// The single failure-policy point for both the cadence writer and the
/// final seal.  A successful save triggers keep-last-K GC when
/// configured (never after a failure: a failed save must not eat the
/// still-good older files).
fn save_and_log(ck: Checkpoint, dir: &Path, keep_last: Option<usize>) {
    let version = ck.version;
    if let Err(e) = ck.save_in(dir) {
        log_warn!("checkpoint at t={version} failed: {e:#}");
        return;
    }
    if let Some(keep) = keep_last {
        match Checkpoint::prune_keep_last(dir, keep) {
            Ok(removed) if !removed.is_empty() => {
                log_debug!("checkpoint GC: pruned {} old file(s)", removed.len());
            }
            Ok(_) => {}
            Err(e) => log_warn!("checkpoint GC in {} failed: {e:#}", dir.display()),
        }
    }
}

/// Synchronous save (the end-of-run seal).
fn write_checkpoint(
    cfg: &ServerConfig,
    t: u64,
    theta: &[f64],
    adadelta: &AdaDelta,
    gate: &DelayGate,
    cursors: &[(u64, u64, u64)],
) {
    if let Some((ck, dir)) = capture_checkpoint(cfg, t, theta, adadelta, gate, cursors) {
        save_and_log(ck, &dir, cfg.keep_last);
    }
}

/// Hand the encode + fsync to a background thread so the update/publish
/// thread never stalls on disk (the save is an O(dim) state snapshot,
/// not an O(m³) rebuild).  Returns the writer handle; `None` when no
/// directory is configured.
fn spawn_checkpoint(
    cfg: &ServerConfig,
    t: u64,
    theta: &[f64],
    adadelta: &AdaDelta,
    gate: &DelayGate,
    cursors: &[(u64, u64, u64)],
) -> Option<std::thread::JoinHandle<()>> {
    let (ck, dir) = capture_checkpoint(cfg, t, theta, adadelta, gate, cursors)?;
    let keep_last = cfg.keep_last;
    Some(std::thread::spawn(move || save_and_log(ck, &dir, keep_last)))
}

/// Run the server until `max_updates` or all workers exit.
pub fn run_server(
    cfg: &ServerConfig,
    published: Arc<Published>,
    rx: Receiver<ToServer>,
) -> ServerOutcome {
    let layout = cfg.layout;
    let slice = &cfg.slice;
    assert!(
        slice.range.end <= layout.len() && !slice.is_empty(),
        "slice [{}, {}) does not fit θ of dim {}",
        slice.range.start,
        slice.range.end,
        layout.len()
    );
    // Everything below is slice-local: θ, gradients, and the optimizer
    // are `dim = slice.len()` long; `layout` is consulted only to map a
    // local index back to its global coordinate for the element-wise
    // prox and the hyperparameter freeze.
    let dim = slice.len();
    let mut theta = published.snapshot().1.as_ref().clone();
    assert_eq!(theta.len(), dim);
    let mut gate = DelayGate::new(cfg.workers, cfg.tau);
    // Freshest gradient per worker (the Σ_k ∇G_k^{(t_k)} aggregation
    // uses the latest push of every live worker).
    let mut slots: Vec<Option<Push>> = (0..cfg.workers).map(|_| None).collect();
    let (mut adadelta, mut t) = match &cfg.resume {
        Some(ck) => {
            // (m, d) — not just θ length, which collides across layouts.
            assert_eq!(
                (ck.m, ck.d),
                (layout.m, layout.d),
                "resume checkpoint is for layout m={}, d={} but the server \
                 runs m={}, d={}",
                ck.m,
                ck.d,
                layout.m,
                layout.d
            );
            assert_eq!(
                ck.theta.len(),
                dim,
                "resume checkpoint carries {} coordinates but this server's \
                 slice [{}, {}) holds {dim}",
                ck.theta.len(),
                slice.range.start,
                slice.range.end
            );
            // The coordinator already published (ck.version, ck.theta);
            // take the checkpoint as the source of truth regardless.
            theta.copy_from_slice(&ck.theta);
            (ck.restore_adadelta(), ck.version)
        }
        None => (AdaDelta::default_for(dim), 0),
    };
    let mut stats = ServerStats::default();
    // `updates` reports the published version: on a resumed run it
    // starts at the checkpoint version even if no new update lands.
    stats.updates = t;
    let clock = Stopwatch::start();
    let mut last_update = 0.0f64;
    let mut last_value = f64::NAN;

    // One keep-alive slot per declared joiner, cleared by that id's
    // first admission (never by an unrelated rejoin).
    let mut joiner_pending = vec![true; cfg.expected_joiners];
    // Latest consistent cursor snapshot (ISSUE 7), refreshed before
    // every publish.  Seeded from the resume checkpoint so a run that
    // seals without a new update re-seals the cursors it inherited.
    let mut cursor_snapshot: Vec<(u64, u64, u64)> =
        cfg.resume.as_ref().map(|ck| ck.cursors.clone()).unwrap_or_default();
    // Outstanding background checkpoint write (at most one in flight).
    let mut ck_writer: Option<std::thread::JoinHandle<()>> = None;
    // Keep serving while any declared joiner is outstanding, even if
    // every current member departed — the joiner's first push (or the
    // channel disconnecting) is what ends the wait, so an elastic run
    // can hand over from its initial workers to late ones.
    while t < cfg.max_updates
        && (gate.live() > 0 || joiner_pending.iter().any(|p| *p))
    {
        let msg = match rx.recv_timeout(std::time::Duration::from_millis(25)) {
            Ok(m) => m,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                // A transport (`ps::net`) keeps its sender open for the
                // whole run, so channel disconnect can't signal the end;
                // observe the shutdown flag here so an externally ended
                // run (watchdog, time limit) never hangs the server
                // loop waiting for traffic that will never come.
                if published.snapshot().2 {
                    break;
                }
                continue;
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break, // all senders dropped
        };
        absorb(msg, &mut gate, &mut slots, &mut stats, cfg.workers, &mut joiner_pending);
        // Drain any queued pushes before checking the gate — keeps the
        // aggregation as fresh as possible without blocking.
        while let Ok(msg) = rx.try_recv() {
            absorb(msg, &mut gate, &mut slots, &mut stats, cfg.workers, &mut joiner_pending);
        }

        if !gate.permits(t) {
            continue;
        }

        // ---- Algorithm 1, server lines 2–5 ----
        let observed_staleness = gate.staleness(t);
        if let Some(s) = observed_staleness {
            stats.staleness.push(s as f64);
        }
        let mut grad = vec![0.0f64; dim];
        let mut value = 0.0f64;
        for slot in slots.iter().flatten() {
            for (g, s) in grad.iter_mut().zip(&slot.grad) {
                *g += s;
            }
            value += slot.value;
        }
        last_value = value;
        if cfg.freeze_hyper {
            // Freeze everything from Z onward, in *global* coordinates:
            // the hyper block may start before, inside, or after this
            // slice's range.
            let z0 = layout.z_range().start;
            let lo = z0.saturating_sub(slice.range.start).min(dim);
            for g in grad[lo..].iter_mut() {
                *g = 0.0;
            }
        }
        let gamma = cfg.prox.at(t);
        apply_update_slice(
            &layout,
            slice,
            &mut theta,
            &mut adadelta,
            &grad,
            cfg.lr,
            gamma,
            cfg.server_shards,
        );
        t += 1;
        // Snapshot the cursor registry *before* publishing: every
        // worker contributing to this update is still blocked in
        // `wait_newer`, so at τ=0 the registry is frozen at exactly
        // `t` consumed windows per worker — publishing first would
        // race the snapshot against workers starting iteration t.
        if let Some(reg) = &cfg.cursors {
            cursor_snapshot =
                reg.lock().unwrap().iter().map(|(&w, &(off, win))| (w, off, win)).collect();
        }
        // Clock metadata rides along with the snapshot so networked
        // workers see the staleness regime they are part of.
        published.publish_meta(
            t,
            theta.clone(),
            PublishMeta {
                live: gate.live() as u64,
                staleness: observed_staleness.unwrap_or(STALENESS_UNKNOWN),
            },
        );
        if cfg.checkpoint_every > 0 && t % cfg.checkpoint_every == 0 {
            // Async write off the publish thread.  If the previous save
            // is still flushing, skip this cadence hit (the final seal
            // below guarantees the run's last state is always saved).
            if ck_writer.as_ref().is_some_and(|h| !h.is_finished()) {
                log_warn!("checkpoint at t={t} skipped: previous save still in flight");
            } else {
                if let Some(h) = ck_writer.take() {
                    let _ = h.join();
                }
                ck_writer =
                    spawn_checkpoint(cfg, t, &theta, &adadelta, &gate, &cursor_snapshot);
            }
        }
        let now = clock.secs();
        stats.iter_secs.push(now - last_update);
        last_update = now;
        stats.updates = t;
    }

    if let Some(h) = ck_writer.take() {
        // Join the in-flight writer first: the synchronous seal below
        // may target the same version/temp path, and run_server must
        // not return with a write still racing in the background.
        let _ = h.join();
    }
    if cfg.checkpoint_every > 0 {
        // Seal the run so a resume continues from the final state (a
        // no-op rewrite when t already landed on a cadence boundary).
        write_checkpoint(cfg, t, &theta, &adadelta, &gate, &cursor_snapshot);
    }
    published.shutdown();
    // Drain remaining messages so worker sends never block (unbounded
    // channel, but be tidy) and keep the departure count honest for
    // exits that arrived after the loop broke (same member-only rule
    // as `absorb`: retire as we count so one worker's exit can't be
    // double-counted and non-members don't count at all).
    while let Ok(msg) = rx.try_recv() {
        if let ToServer::WorkerExit { worker } = msg {
            if !gate.is_retired(worker) {
                stats.leaves += 1;
                gate.retire(worker);
            }
        }
    }
    // Fold in the transport faults the accept loop absorbed on our
    // behalf (ISSUE 6) — the loop above never saw them, by design.
    if let Some(ctr) = &cfg.transport_faults {
        stats.faults = ctr.load(Ordering::Relaxed);
    }
    // Likewise the store chunks the workers' readers quarantined
    // (ISSUE 7): degraded reads never surface in the loop, only here.
    if let Some(ctr) = &cfg.store_quarantines {
        stats.store_quarantines = ctr.load(Ordering::Relaxed);
    }
    ServerOutcome { theta, stats, last_value }
}

/// One server update: ADADELTA-scaled gradient step + prox projection,
/// optionally parallelized element-wise across `shards` threads — the
/// paper's "element-wise, closed-form … highly parallelizable" claim.
pub fn apply_update(
    layout: &ThetaLayout,
    theta: &mut [f64],
    adadelta: &mut AdaDelta,
    grad: &[f64],
    lr: f64,
    gamma: f64,
    shards: usize,
) {
    let delta = adadelta.step(grad);
    if shards <= 1 {
        for (t, d) in theta.iter_mut().zip(&delta) {
            *t += lr * d;
        }
        prox_update(layout, theta, gamma);
    } else {
        // Element-wise partition: every shard owns a contiguous slice of
        // θ, applies the gradient step and its slice of the prox without
        // any cross-shard communication.
        let dim = theta.len();
        let chunk = dim.div_ceil(shards);
        let layout = *layout;
        let scale = 1.0 / (1.0 + gamma);
        std::thread::scope(|scope| {
            for (si, (t_chunk, d_chunk)) in theta
                .chunks_mut(chunk)
                .zip(delta.chunks(chunk))
                .enumerate()
            {
                scope.spawn(move || {
                    let base = si * chunk;
                    for (off, (t, d)) in
                        t_chunk.iter_mut().zip(d_chunk).enumerate()
                    {
                        *t += lr * d;
                        let idx = base + off;
                        // Element-wise prox (eqs. 18–20).
                        if layout.is_variational(idx) {
                            if layout.is_u_diag(idx) {
                                let up = *t;
                                *t = (up
                                    + (up * up + 4.0 * (1.0 + gamma) * gamma)
                                        .sqrt())
                                    / (2.0 * (1.0 + gamma));
                            } else {
                                *t *= scale;
                            }
                        }
                    }
                });
            }
        });
    }
}

/// One server update restricted to a θ slice: the ADADELTA-scaled
/// gradient step plus the element-wise prox (eqs. 18–20), applied per
/// coordinate with the *global* index deciding which projection rule
/// applies.  For [`SliceSpec::full`] this is bitwise-identical to
/// [`apply_update`] with `shards = 1` (same per-element arithmetic as
/// [`prox_update`], just a different iteration order over independent
/// coordinates) — pinned by `full_slice_update_matches_apply_update`.
/// `shards > 1` parallelizes element-wise *within* the slice, exactly
/// as `apply_update` does across the whole vector.
#[allow(clippy::too_many_arguments)]
pub fn apply_update_slice(
    layout: &ThetaLayout,
    slice: &SliceSpec,
    theta: &mut [f64],
    adadelta: &mut AdaDelta,
    grad: &[f64],
    lr: f64,
    gamma: f64,
    shards: usize,
) {
    assert_eq!(theta.len(), slice.len());
    let delta = adadelta.step(grad);
    let scale = 1.0 / (1.0 + gamma);
    let base = slice.range.start;
    // The per-coordinate rule (identical arithmetic to `prox_update`).
    let elem = |global: usize, t: &mut f64, d: f64| {
        *t += lr * d;
        if layout.is_variational(global) {
            if layout.is_u_diag(global) {
                let up = *t;
                *t = (up + (up * up + 4.0 * (1.0 + gamma) * gamma).sqrt())
                    / (2.0 * (1.0 + gamma));
            } else {
                *t *= scale;
            }
        }
    };
    if shards <= 1 {
        for (i, (t, d)) in theta.iter_mut().zip(&delta).enumerate() {
            elem(base + i, t, *d);
        }
    } else {
        let chunk = theta.len().div_ceil(shards);
        std::thread::scope(|scope| {
            for (si, (t_chunk, d_chunk)) in
                theta.chunks_mut(chunk).zip(delta.chunks(chunk)).enumerate()
            {
                let elem = &elem;
                scope.spawn(move || {
                    for (off, (t, d)) in t_chunk.iter_mut().zip(d_chunk).enumerate() {
                        elem(base + si * chunk + off, t, *d);
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn sharded_update_matches_serial() {
        let layout = ThetaLayout::new(6, 3);
        let dim = layout.len();
        let mut rng = Pcg64::seeded(3);
        let theta0: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let grad: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let mut serial = theta0.clone();
        let mut ada1 = AdaDelta::default_for(dim);
        apply_update(&layout, &mut serial, &mut ada1, &grad, 0.7, 0.3, 1);
        for shards in [2, 3, 5, 16] {
            let mut sharded = theta0.clone();
            let mut ada = AdaDelta::default_for(dim);
            apply_update(&layout, &mut sharded, &mut ada, &grad, 0.7, 0.3, shards);
            for (a, b) in serial.iter().zip(&sharded) {
                assert!((a - b).abs() < 1e-12, "shards={shards}");
            }
        }
    }

    /// The slice-update path with a full slice is the single-server
    /// update, **bitwise** — the parity the whole partitioned topology
    /// rests on.
    #[test]
    fn full_slice_update_matches_apply_update() {
        let layout = ThetaLayout::new(5, 3);
        let dim = layout.len();
        let mut rng = Pcg64::seeded(9);
        let theta0: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let mut single = theta0.clone();
        let mut sliced = theta0.clone();
        let mut ada_a = AdaDelta::default_for(dim);
        let mut ada_b = AdaDelta::default_for(dim);
        let full = SliceSpec::full(dim);
        for step in 0..6 {
            let grad: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
            let gamma = 0.05 / (1.0 + step as f64 / 3.0);
            apply_update(&layout, &mut single, &mut ada_a, &grad, 0.8, gamma, 1);
            apply_update_slice(&layout, &full, &mut sliced, &mut ada_b, &grad, 0.8, gamma, 1);
            for (i, (a, b)) in single.iter().zip(&sliced).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "step {step} θ[{i}]");
            }
        }
    }

    /// S independent slice servers — each with its own optimizer over
    /// its range — compose to the full update bitwise: element-wise
    /// separability, the paper's server-side parallelism claim taken to
    /// the process level.
    #[test]
    fn disjoint_slices_compose_to_the_full_update_bitwise() {
        use crate::ps::sharded::Topology;
        let layout = ThetaLayout::new(6, 2);
        let dim = layout.len();
        let mut rng = Pcg64::seeded(23);
        let theta0: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let grads: Vec<Vec<f64>> =
            (0..5).map(|_| (0..dim).map(|_| rng.normal()).collect()).collect();
        // Reference: one full-vector server.
        let mut full_theta = theta0.clone();
        let mut full_ada = AdaDelta::default_for(dim);
        for g in &grads {
            apply_update_slice(
                &layout,
                &SliceSpec::full(dim),
                &mut full_theta,
                &mut full_ada,
                g,
                1.0,
                0.2,
                1,
            );
        }
        for s in [2, 3, 4] {
            let topo = Topology::partition(dim, s);
            let mut parts: Vec<Vec<f64>> =
                topo.ranges.iter().map(|r| theta0[r.clone()].to_vec()).collect();
            let mut adas: Vec<AdaDelta> =
                topo.ranges.iter().map(|r| AdaDelta::default_for(r.end - r.start)).collect();
            for g in &grads {
                for i in 0..s {
                    let spec = topo.slice(i);
                    let frag = g[spec.range.clone()].to_vec();
                    apply_update_slice(
                        &layout,
                        &spec,
                        &mut parts[i],
                        &mut adas[i],
                        &frag,
                        1.0,
                        0.2,
                        1,
                    );
                }
            }
            let assembled: Vec<f64> = parts.concat();
            for (i, (a, b)) in full_theta.iter().zip(&assembled).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "S={s} θ[{i}]");
            }
        }
    }
}
