//! The parameter server — ADVGP's L3 system contribution (paper §4,
//! Algorithm 1).
//!
//! Topology: one **server** (optionally sharded element-wise for the
//! update step), `r` **workers** each owning a data shard, and one
//! **evaluator** thread producing the RMSE/MNLP/−ELBO-vs-time traces
//! every figure in the paper is drawn from.
//!
//! Protocol (Algorithm 1):
//! * Worker k: block until a version newer than its last pull is
//!   published → pull θ^(t) → compute ∇G_k over D_k → push.
//! * Server: on every push, record `(t_k, ∇G_k)`; when the bounded-
//!   staleness gate `min_k t_k ≥ t − τ` holds (and every worker has
//!   pushed at least once), aggregate the *latest* gradient of every
//!   worker, take an ADADELTA-scaled gradient step, apply the
//!   closed-form proximal projection (eqs. 18–20) to (μ, U), bump the
//!   version, and notify all blocked workers.
//!
//! τ = 0 degenerates to bulk-synchronous (the DistGP-GD baseline runs
//! exactly this path); τ = ∞ is fully asynchronous.
//!
//! Elasticity & durability (ISSUE 3): membership is dynamic — a
//! departed worker's clock is *retired* from the gate so the run
//! proceeds without it, and a late joiner is admitted by its first
//! push after adopting the live published θ (see [`coordinator::Joiner`]
//! and [`delay::DelayGate`]).  The server periodically freezes
//! (θ, t, ADADELTA state, worker clocks) into an atomic, versioned
//! [`checkpoint::Checkpoint`] file (with keep-last-K GC — see
//! [`coordinator::TrainConfig::keep_last`]), and
//! `TrainConfig::resume_from` restarts a run from one bitwise.  Workers
//! can stream their shard from the out-of-core [`crate::data::store`]
//! instead of holding it resident ([`worker::WorkerSource`]).
//!
//! Transports (ISSUE 4): the server loop, [`DelayGate`], and the worker
//! loop are transport-agnostic — they speak [`messages::ToServer`] and
//! [`Published`].  In-process those travel over an `mpsc` channel and a
//! condvar; across machines the same messages travel as `ADVGPNT1`
//! frames over TCP ([`wire`] is the codec, [`net`] the pumps — see
//! `docs/PROTOCOL.md`), and [`coordinator::train_remote`] /
//! [`net::remote_worker_loop`] wire the two halves up.
//!
//! Partitioning (ISSUE 5): θ itself can be sharded into `S` disjoint
//! contiguous slices, each owned by an independent server loop — the
//! element-wise prox/ADADELTA make slice servers need no cross-slice
//! communication at all.  [`sharded`] holds the partition map and the
//! assembler/splitter pumps; over the wire the `ADVGPNT2` revision
//! (negotiated per connection; revision-1 peers keep working against a
//! single-slice server) carries `(slice_id, range)` in
//! WELCOME2/PUBLISH2/PUSH2 frames.  `TrainConfig::servers` switches the
//! in-process coordinator; [`coordinator::train_remote_sharded`] /
//! [`net::sharded_worker_loop`] are the networked pair, and
//! `advgp serve-ps --servers S` / `--slice i/S` the CLI.  At τ = 0 a
//! sharded run reproduces the single-server θ trajectory **bitwise**
//! (`rust/tests/sharded_ps.rs`).
//!
//! Storage robustness (ISSUE 7): out-of-core shards live in the
//! checksummed `ADVGPSH2` chunk format; a read that fails verification
//! quarantines the chunk and training continues **degraded** under a
//! session-wide corruption budget (typed
//! [`crate::data::store::StoreFault`] when it runs dry).  Workers
//! record `(initial offset, consumed windows)` stream cursors into a
//! [`worker::CursorRegistry`] the server freezes into every checkpoint,
//! making streamed-store τ=0 resume bitwise end-to-end; the
//! [`fault::StoreFaultPlan`] seeded disk-fault layer drives the
//! `chaos_store` test matrix.

pub mod checkpoint;
pub mod coordinator;
pub mod delay;
pub mod fault;
pub mod messages;
pub mod metrics;
pub mod net;
pub mod server;
pub mod sharded;
pub mod wire;
pub mod worker;

pub use checkpoint::Checkpoint;
pub use coordinator::{
    train, train_elastic, train_published, train_remote, train_remote_sharded,
    train_remote_slice, train_sources, Joiner, RunResult, TrainConfig,
};
pub use delay::DelayGate;
pub use fault::{
    FaultEvent, FaultPlan, FaultProxy, FaultRule, StoreFaultEvent, StoreFaultPlan,
    StoreFaultRule,
};
pub use messages::PublishMeta;
pub use metrics::{EvalMetrics, TraceRow};
pub use net::{
    remote_worker_loop, remote_worker_loop_with, sharded_worker_loop,
    sharded_worker_loop_with, NetServer, NetWorkerHandle, ReconnectPolicy, RetryPolicy,
    ShardedWorkerHandle,
};
pub use sharded::{ShardedPublished, SliceSpec, Topology};
pub use worker::{CursorRegistry, ShardInbox, StorePool, WorkerProfile, WorkerSource};

use std::sync::{Arc, Condvar, Mutex};

/// The server's published state: workers pull from here.
pub struct Published {
    pub inner: Mutex<PublishedInner>,
    pub cv: Condvar,
}

pub struct PublishedInner {
    pub version: u64,
    pub theta: Arc<Vec<f64>>,
    /// Gate-clock metadata of the aggregation that produced `version`
    /// (default/unknown for seeded or resume-republished snapshots).
    pub meta: PublishMeta,
    pub shutdown: bool,
}

impl Published {
    pub fn new(theta: Vec<f64>) -> Arc<Self> {
        Arc::new(Self {
            inner: Mutex::new(PublishedInner {
                version: 0,
                theta: Arc::new(theta),
                meta: PublishMeta::default(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        })
    }

    /// Publish a new version (server side) with no clock metadata —
    /// the coordinator's resume republish and tests use this.
    pub fn publish(&self, version: u64, theta: Vec<f64>) {
        self.publish_meta(version, theta, PublishMeta::default());
    }

    /// Publish a new version with the gate-clock metadata the networked
    /// transport forwards to remote workers in PUBLISH frames.
    pub fn publish_meta(&self, version: u64, theta: Vec<f64>, meta: PublishMeta) {
        let mut g = self.inner.lock().unwrap();
        g.version = version;
        g.theta = Arc::new(theta);
        g.meta = meta;
        self.cv.notify_all();
    }

    /// Signal shutdown to all blocked workers.
    pub fn shutdown(&self) {
        let mut g = self.inner.lock().unwrap();
        g.shutdown = true;
        self.cv.notify_all();
    }

    /// Worker side: block until `version > seen` (or shutdown).
    /// Returns `None` on shutdown.
    pub fn wait_newer(&self, seen: u64) -> Option<(u64, Arc<Vec<f64>>)> {
        self.wait_newer_meta(seen).map(|(v, th, _)| (v, th))
    }

    /// [`Published::wait_newer`] plus the version's clock metadata —
    /// the per-connection publish fan-out of [`net`] uses this.
    pub fn wait_newer_meta(
        &self,
        seen: u64,
    ) -> Option<(u64, Arc<Vec<f64>>, PublishMeta)> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.shutdown {
                return None;
            }
            if g.version > seen {
                return Some((g.version, g.theta.clone(), g.meta));
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// [`Published::wait_newer_meta`] with **draining** semantics: a
    /// version newer than `seen` is delivered even when shutdown has
    /// already been signalled — `None` means shutdown *and* nothing
    /// newer to hand out.  `wait_newer_meta` checks shutdown first,
    /// which is right for workers (a gradient against a dead run is
    /// wasted compute) but loses the final θ when the server's last
    /// publish and its shutdown race; the serving path's subscriber
    /// fan-out (ADVGPSV1) must deliver that final version, so replicas
    /// end bitwise-equal to the trainer.
    pub fn wait_newer_draining(
        &self,
        seen: u64,
    ) -> Option<(u64, Arc<Vec<f64>>, PublishMeta)> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.version > seen {
                return Some((g.version, g.theta.clone(), g.meta));
            }
            if g.shutdown {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Non-blocking snapshot (evaluator side).
    pub fn snapshot(&self) -> (u64, Arc<Vec<f64>>, bool) {
        let g = self.inner.lock().unwrap();
        (g.version, g.theta.clone(), g.shutdown)
    }

    /// Non-blocking snapshot including clock metadata (the networked
    /// handshake's initial PUBLISH uses this).
    pub fn snapshot_meta(&self) -> (u64, Arc<Vec<f64>>, PublishMeta, bool) {
        let g = self.inner.lock().unwrap();
        (g.version, g.theta.clone(), g.meta, g.shutdown)
    }

    /// Block until shutdown is signalled or `timeout` elapses; returns
    /// true on shutdown.  Late joiners wait out their join delay with
    /// this instead of a raw sleep, so a run that ends early never has
    /// to sit through the full delay before `train_elastic` can return.
    pub fn shutdown_or_timeout(&self, timeout: std::time::Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.shutdown {
                return true;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            // Publishes also notify this condvar; the deadline check
            // above absorbs those (and spurious) wakeups.
            g = self.cv.wait_timeout(g, deadline - now).unwrap().0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn publish_wakes_waiters() {
        let p = Published::new(vec![0.0; 3]);
        let p2 = p.clone();
        let h = std::thread::spawn(move || p2.wait_newer(0));
        std::thread::sleep(Duration::from_millis(20));
        p.publish(1, vec![1.0, 2.0, 3.0]);
        let (v, th) = h.join().unwrap().expect("should get version");
        assert_eq!(v, 1);
        assert_eq!(*th, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn shutdown_unblocks() {
        let p = Published::new(vec![0.0]);
        let p2 = p.clone();
        let h = std::thread::spawn(move || p2.wait_newer(100));
        std::thread::sleep(Duration::from_millis(20));
        p.shutdown();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn snapshot_is_nonblocking() {
        let p = Published::new(vec![7.0]);
        let (v, th, sd) = p.snapshot();
        assert_eq!(v, 0);
        assert_eq!(*th, vec![7.0]);
        assert!(!sd);
    }

    /// Clock metadata rides along with the version it was produced at,
    /// and seeded/plain publishes report the unknown default.
    #[test]
    fn publish_meta_travels_with_the_version() {
        let p = Published::new(vec![0.0]);
        let (_, _, meta, _) = p.snapshot_meta();
        assert_eq!(meta, PublishMeta::default());
        let m = PublishMeta { live: 3, staleness: 1 };
        p.publish_meta(5, vec![2.0], m);
        let (v, th, got) = p.wait_newer_meta(0).unwrap();
        assert_eq!((v, got), (5, m));
        assert_eq!(*th, vec![2.0]);
        // Plain publish resets to the unknown default.
        p.publish(6, vec![3.0]);
        let (_, _, got, _) = p.snapshot_meta();
        assert_eq!(got, PublishMeta::default());
    }

    /// The draining wait delivers a final publish that raced shutdown
    /// (the worker-side wait drops it by design), then reports the
    /// shutdown.
    #[test]
    fn draining_wait_delivers_the_final_version_before_shutdown() {
        let p = Published::new(vec![0.0]);
        // Publish and shutdown already both applied — the racing case.
        p.publish(3, vec![9.0]);
        p.shutdown();
        // Worker semantics: shutdown wins, the final version is lost.
        assert!(p.wait_newer_meta(2).is_none());
        // Draining semantics: the final version is delivered first …
        let (v, th, _) = p.wait_newer_draining(2).unwrap();
        assert_eq!(v, 3);
        assert_eq!(*th, vec![9.0]);
        // … and only then does the wait report shutdown.
        assert!(p.wait_newer_draining(3).is_none());
    }

    /// A joiner's delay wait must end immediately on shutdown (not sit
    /// out the timeout) and report which way it woke.
    #[test]
    fn shutdown_or_timeout_wakes_on_shutdown() {
        let p = Published::new(vec![0.0]);
        // Timeout path: far-future shutdown never arrives.
        assert!(!p.shutdown_or_timeout(Duration::from_millis(10)));
        // Shutdown path: signalled mid-wait, returns well before the
        // 60 s timeout.
        let p2 = p.clone();
        let h = std::thread::spawn(move || {
            let t0 = std::time::Instant::now();
            let sd = p2.shutdown_or_timeout(Duration::from_secs(60));
            (sd, t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(20));
        p.shutdown();
        let (sd, waited) = h.join().unwrap();
        assert!(sd);
        assert!(waited < Duration::from_secs(10));
        // Already shut down: returns true without waiting.
        assert!(p.shutdown_or_timeout(Duration::from_secs(60)));
    }
}
