//! Wiring: spawn server(s) + workers (+ late joiners) + evaluator, run
//! to completion, collect traces.  This is the entry point every
//! experiment uses.
//!
//! Topologies (all sharing the same server loop, gate, and worker
//! math):
//!
//! * [`train`] / [`train_sources`] / [`train_elastic`] — in-process.
//!   With [`TrainConfig::servers`] > 1 the same calls transparently run
//!   the **partitioned** topology (ISSUE 5): θ is tiled into `S`
//!   contiguous slices, one independent server loop each, with an
//!   assembler presenting workers the full-θ view and a splitter
//!   fanning each gradient out per slice — at τ = 0 bitwise-identical
//!   to the single-server trajectory (`rust/tests/sharded_ps.rs`).
//! * [`train_remote`] — one θ-server over TCP (`ADVGPNT1`/`2`).
//! * [`train_remote_sharded`] — `S` slice servers over TCP, one
//!   listener each, workers connecting to all of them
//!   ([`super::net::ShardedWorkerHandle`]).
//! * [`train_remote_slice`] — exactly one slice server, for
//!   multi-process deployments (`advgp serve-ps --slice i/S`), where
//!   every slice runs in its own process and no single process holds
//!   all of θ.

use super::checkpoint::{self, Checkpoint};
use super::messages::ToServer;
use super::metrics::{EvalMetrics, ServerStats, TraceRow};
use super::server::{run_server, ServerConfig, ServerOutcome};
use super::sharded::{
    merge_outcomes, run_assembler, run_splitter, ShardedPublished, SliceSpec, Topology,
};
use super::worker::{
    run_worker, CursorRegistry, ShardInbox, StorePool, WorkerProfile, WorkerSource,
};
use super::Published;
use crate::data::store::QuarantinePolicy;
use crate::data::Dataset;
use crate::gp::ThetaLayout;
use crate::grad::EngineFactory;
use crate::log_warn;
use crate::opt::StepSchedule;
use crate::runtime::backend::{self, Backend};
use crate::util::Stopwatch;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::AtomicU64;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

/// Evaluation closure, constructed *inside* the evaluator thread
/// (PJRT evaluators are not Send).  Called as `(version, θ)` so the
/// evaluator can key posterior caches by the published version.
pub type EvalFactory =
    Box<dyn FnOnce() -> Box<dyn FnMut(u64, &[f64]) -> EvalMetrics> + Send>;

pub struct TrainConfig {
    pub layout: ThetaLayout,
    pub tau: u64,
    /// Cumulative published-version ceiling — see
    /// [`ServerConfig::max_updates`](super::server::ServerConfig).
    pub max_updates: u64,
    /// Learning-rate scale on the ADADELTA direction (paper §6.1).
    pub lr: f64,
    /// Proximal strength γ_t schedule.
    pub prox: StepSchedule,
    /// θ-slice server count (ISSUE 5): 1 = the classic single server;
    /// S > 1 partitions θ into S contiguous slices, each owned by an
    /// independent server loop with its own gate, optimizer state, and
    /// checkpoints.  At τ=0 the trajectory is bitwise-identical for
    /// every S.
    pub servers: usize,
    /// Element-wise threads *within* each server's update step (the
    /// paper's "highly parallelizable" prox; orthogonal to `servers`).
    pub server_shards: usize,
    pub freeze_hyper: bool,
    /// Per-worker behaviour; padded with defaults if shorter than the
    /// number of shards.
    pub profiles: Vec<WorkerProfile>,
    /// Evaluator cadence (seconds). 0 disables intermediate snapshots.
    pub eval_every_secs: f64,
    /// Hard wall-clock limit; the run is shut down when exceeded.
    pub time_limit_secs: Option<f64>,
    /// Thread-pool budget per worker for its gradient linalg
    /// (0 = auto: `util::pool::threads()` split evenly across workers,
    /// min 1).  Individual `WorkerProfile::threads` values override.
    pub worker_threads: usize,
    /// Write a server-state checkpoint every N updates into
    /// `checkpoint_dir` (0 = never).  See [`crate::ps::checkpoint`].
    /// Sharded runs write per-slice files under
    /// `checkpoint_dir/slice_*/` plus a topology manifest at the root.
    pub checkpoint_every: u64,
    pub checkpoint_dir: Option<PathBuf>,
    /// Checkpoint retention: after every successful save keep only the
    /// newest K files in `checkpoint_dir` (`None` = keep all; clamped
    /// to ≥ 1 so the final seal always survives).  Sharded runs prune
    /// per slice directory.  See [`Checkpoint::prune_keep_last`].
    pub keep_last: Option<usize>,
    /// Resume from a frozen server state (load it with
    /// [`Checkpoint::load`] / [`Checkpoint::load_latest_any`] — the
    /// latter reassembles sharded directories): the run publishes
    /// `(ck.version, ck.θ)` before any worker starts, and θ, the
    /// version counter, and the ADADELTA accumulators restore bitwise.
    /// Because every server-side quantity is element-wise, a sharded
    /// run can resume a single-server checkpoint and vice versa.
    pub resume_from: Option<Checkpoint>,
    /// Heartbeat idle window for networked transports (seconds; 0
    /// disables): after this much read silence on a revision-2
    /// connection the server PINGs, and a peer silent through a second
    /// window is retired as wedged.  In-process runs ignore it.
    pub heartbeat_secs: f64,
    /// Opaque id stamped into the checkpoint directory's lineage
    /// manifest ([`checkpoint::append_lineage`]) when this run seals —
    /// generated per config; override to correlate with external
    /// schedulers.
    pub run_id: String,
    /// Compute backend installed process-wide at the start of the run
    /// (ISSUE 10): every worker gradient engine and evaluator posterior
    /// built after that point inherits it.  Defaults to the
    /// `ADVGP_BACKEND` env selection (scalar when unset).
    pub backend: Backend,
}

impl TrainConfig {
    pub fn new(layout: ThetaLayout) -> Self {
        Self {
            layout,
            tau: 32, // the paper's tuned default for the flight runs
            max_updates: 500,
            lr: 1.0,
            prox: StepSchedule::new(0.05, 200.0),
            servers: 1,
            server_shards: 1,
            freeze_hyper: false,
            profiles: vec![],
            eval_every_secs: 0.5,
            time_limit_secs: None,
            worker_threads: 0,
            checkpoint_every: 0,
            checkpoint_dir: None,
            keep_last: None,
            resume_from: None,
            heartbeat_secs: 30.0,
            run_id: gen_run_id(),
            backend: Backend::from_env(),
        }
    }
}

/// A per-process, per-instant run id for the lineage manifest — opaque,
/// collision-resistant enough for provenance display (FNV-1a over the
/// wall clock and pid).
fn gen_run_id() -> String {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    let h = crate::util::fnv1a64(crate::util::FNV1A64_INIT, &nanos.to_le_bytes());
    let h = crate::util::fnv1a64(h, &std::process::id().to_le_bytes());
    format!("{h:016x}")
}

/// Append this run's lineage record to the checkpoint directory —
/// best-effort, same durability policy as checkpoint saves (a failed
/// append warns and never fails the run).
fn record_lineage(cfg: &TrainConfig, step: u64, wall_secs: f64) {
    if cfg.checkpoint_every == 0 {
        return;
    }
    let Some(dir) = &cfg.checkpoint_dir else { return };
    let rec = checkpoint::LineageRecord {
        run_id: cfg.run_id.clone(),
        resumed_from: cfg.resume_from.as_ref().map(|c| c.version),
        step,
        wall_secs,
    };
    if let Err(e) = checkpoint::append_lineage(dir, rec) {
        log_warn!("lineage manifest append in {} failed: {e:#}", dir.display());
    }
}

/// A worker that enters the run late (ISSUE 3 elasticity): after
/// `after`, it snapshots the live published version — *adopting* the
/// current θ — and joins the push/pull loop.  The server admits it on
/// its first push.
pub struct Joiner {
    pub after: Duration,
    pub source: WorkerSource,
    pub profile: WorkerProfile,
}

pub struct RunResult {
    pub theta: Vec<f64>,
    pub trace: Vec<TraceRow>,
    pub stats: ServerStats,
    pub wall_secs: f64,
}

/// Train ADVGP: Algorithm 1 end-to-end over the given resident shards.
pub fn train(
    cfg: &TrainConfig,
    theta0: Vec<f64>,
    shards: Vec<Dataset>,
    factory: EngineFactory,
    eval_factory: Option<EvalFactory>,
) -> RunResult {
    let sources = shards.into_iter().map(WorkerSource::Memory).collect();
    train_elastic(cfg, Published::new(theta0), sources, vec![], factory, eval_factory)
}

/// [`train`] over arbitrary worker data sources — resident datasets or
/// out-of-core [`crate::data::store::ShardReader`]s (typically a
/// [`crate::data::store::ShardSet`]'s readers).
pub fn train_sources(
    cfg: &TrainConfig,
    theta0: Vec<f64>,
    sources: Vec<WorkerSource>,
    factory: EngineFactory,
    eval_factory: Option<EvalFactory>,
) -> RunResult {
    train_elastic(cfg, Published::new(theta0), sources, vec![], factory, eval_factory)
}

/// [`train`] against a caller-owned [`Published`] handle (seeded with
/// θ₀).  This lets a serving stack — e.g. a `serve::BatchServer`
/// syncing its `PosteriorCache` — follow the live θ *while training
/// runs* (see `examples/serve_latency.rs`); `train` is the
/// convenience wrapper that creates the handle itself.  In a sharded
/// run the handle is the assembled view, so the serving stack is
/// equally topology-blind.
pub fn train_published(
    cfg: &TrainConfig,
    published: std::sync::Arc<Published>,
    shards: Vec<Dataset>,
    factory: EngineFactory,
    eval_factory: Option<EvalFactory>,
) -> RunResult {
    let sources = shards.into_iter().map(WorkerSource::Memory).collect();
    train_elastic(cfg, published, sources, vec![], factory, eval_factory)
}

/// Layout guard shared by every resume path: compare (m, d), not just
/// θ length — distinct layouts can collide on dimension (e.g. m=1,d=5
/// and m=2,d=2 both give 14), and restoring across that collision would
/// silently slice every θ block at the wrong offsets.
fn check_resume_layout(ck: &Checkpoint, layout: &ThetaLayout) {
    assert_eq!(
        (ck.m, ck.d),
        (layout.m, layout.d),
        "resume checkpoint is for layout m={}, d={} but this run uses \
         m={}, d={}",
        ck.m,
        ck.d,
        layout.m,
        layout.d
    );
}

/// Lower a [`TrainConfig`] into one slice server's config.  The full
/// slice with the root checkpoint dir for single-server runs; a proper
/// sub-range plus its `slice_*/` checkpoint directory (and its share of
/// a resumed state) for sharded runs.
fn slice_server_config(
    cfg: &TrainConfig,
    workers: usize,
    expected_joiners: usize,
    slice: SliceSpec,
    checkpoint_dir: Option<PathBuf>,
    resume: Option<Checkpoint>,
) -> ServerConfig {
    ServerConfig {
        layout: cfg.layout,
        slice,
        workers,
        tau: cfg.tau,
        max_updates: cfg.max_updates,
        lr: cfg.lr,
        prox: cfg.prox,
        server_shards: cfg.server_shards,
        freeze_hyper: cfg.freeze_hyper,
        checkpoint_every: cfg.checkpoint_every,
        checkpoint_dir,
        keep_last: cfg.keep_last,
        resume,
        expected_joiners,
        // Only the networked coordinators wire a live counter in (the
        // transport is the only fault surface); in-process runs report 0.
        transport_faults: None,
        // The in-process topologies install these after lowering (ISSUE
        // 7); the networked paths leave them unset — remote workers keep
        // their own cursors and resume from the stream head.
        cursors: None,
        store_quarantines: None,
    }
}

/// The single-server lowering (full slice, root checkpoint dir).
fn server_config(cfg: &TrainConfig, workers: usize, expected_joiners: usize) -> ServerConfig {
    slice_server_config(
        cfg,
        workers,
        expected_joiners,
        SliceSpec::full(cfg.layout.len()),
        cfg.checkpoint_dir.clone(),
        cfg.resume_from.clone(),
    )
}

/// Write (or validate) the sharded run's topology manifest.  A
/// [`checkpoint::TopologyConflict`] (different or unreadable existing
/// manifest) is a configuration error and loud — silently checkpointing
/// a different partition into per-slice directories the old manifest
/// does not name would make the next resume restore stale state.  A
/// plain IO failure follows the checkpoint durability policy (warn,
/// training outlives it).
fn ensure_topology_manifest(root: &std::path::Path, layout: ThetaLayout, topo: &Topology) {
    if let Err(e) = Checkpoint::save_topology(root, layout, topo) {
        if e.downcast_ref::<checkpoint::TopologyConflict>().is_some() {
            panic!("{e:#} (delete the directory or match --servers)");
        }
        log_warn!(
            "topology manifest write in {} failed: {e:#} — sharded resume \
             from this directory will not work",
            root.display()
        );
    }
}

/// Prepare a sharded run's checkpoint layout: the topology manifest at
/// the root (validated against any existing manifest — re-partitioning
/// a directory in place is an error) and the per-slice directory for
/// each server.  Also re-slices a resumed checkpoint.
fn sharded_checkpoint_dirs(
    cfg: &TrainConfig,
    topo: &Topology,
) -> Vec<(Option<PathBuf>, Option<Checkpoint>)> {
    let root = cfg.checkpoint_dir.as_ref();
    if cfg.checkpoint_every > 0 {
        if let Some(root) = root {
            ensure_topology_manifest(root, cfg.layout, topo);
        }
    }
    (0..topo.n_slices())
        .map(|i| {
            let dir = root.map(|r| Checkpoint::slice_dir(r, i, topo.n_slices()));
            let resume = cfg
                .resume_from
                .as_ref()
                .map(|ck| ck.slice_of(topo.ranges[i].clone()));
            (dir, resume)
        })
        .collect()
}

/// Resolve per-worker thread budgets.  Explicit budgets (profile or
/// `cfg.worker_threads`) are honored as-is; the remaining pool capacity
/// is split across the auto workers with the remainder distributed
/// one-by-one, so no core is left permanently idle by integer
/// truncation and explicit budgets aren't double-counted.  (Joiners
/// keep their own profile budgets: honored as-is, min 1.)
fn resolve_profiles(cfg: &TrainConfig, workers: usize) -> Vec<WorkerProfile> {
    let mut profiles: Vec<WorkerProfile> = (0..workers)
        .map(|k| cfg.profiles.get(k).cloned().unwrap_or_default())
        .collect();
    if cfg.worker_threads > 0 {
        for p in profiles.iter_mut().filter(|p| p.threads == 0) {
            p.threads = cfg.worker_threads;
        }
    }
    let explicit: usize = profiles.iter().map(|p| p.threads).sum();
    let auto_count = profiles.iter().filter(|p| p.threads == 0).count();
    if auto_count > 0 {
        let avail = crate::util::pool::threads()
            .saturating_sub(explicit)
            .max(auto_count); // every worker gets at least one lane
        let base = avail / auto_count;
        let extra = avail % auto_count;
        for (i, p) in profiles.iter_mut().filter(|p| p.threads == 0).enumerate() {
            p.threads = (base + usize::from(i < extra)).max(1);
        }
    }
    profiles
}

/// ISSUE 7 wiring shared by the in-process topologies: one cursor
/// registry every worker records `(initial offset, consumed windows)`
/// into — so checkpoints capture exact stream positions — and one
/// quarantine policy (corruption budget + shared counter) to install on
/// every out-of-core source.  Resumed checkpoint cursors are mapped
/// back onto the initial worker ids that recorded them; a cursor for an
/// id beyond `profiles` (a joiner of the sealed run) is dropped —
/// joiners re-enter by wall clock, outside the bitwise-resume contract.
fn wire_store_robustness(
    cfg: &TrainConfig,
    profiles: &mut [WorkerProfile],
) -> (CursorRegistry, QuarantinePolicy) {
    let cursors: CursorRegistry = Arc::new(Mutex::new(BTreeMap::new()));
    let quarantine = QuarantinePolicy::new_default();
    for p in profiles.iter_mut() {
        p.cursors = Some(cursors.clone());
    }
    if let Some(ck) = &cfg.resume_from {
        for &(w, off, windows) in &ck.cursors {
            if let Some(p) = profiles.get_mut(w as usize) {
                p.resume_cursor = Some((off, windows));
            }
        }
    }
    (cursors, quarantine)
}

/// Wrap an out-of-core source in a [`StorePool`] on the run's shared
/// shard inbox (ISSUE 6 failure-domain hardening): a worker that leaves
/// early surrenders its shard readers to the inbox, and any surviving
/// pool worker adopts them before its next window — the departed
/// worker's slice of the data keeps flowing into the posterior instead
/// of silently dropping out of the run.  Resident (`Memory`) sources
/// pass through untouched: their data lives only in the departing
/// worker's address space, so there is nothing durable to hand over.
fn pool_source(k: usize, source: WorkerSource, inbox: &ShardInbox) -> WorkerSource {
    match source {
        WorkerSource::Store(reader) => {
            WorkerSource::Pool(StorePool::new(k, reader, inbox.clone()))
        }
        WorkerSource::Pool(mut pool) => {
            // A pre-built pool (a repartitioned reader group, ISSUE 7)
            // joins the run's shared inbox so surrender/adopt spans
            // every pool worker.
            pool.rehome(inbox.clone());
            WorkerSource::Pool(pool)
        }
        other => other,
    }
}

/// Run one worker to completion, then surrender its pooled shards if
/// the run is still live (on a shutdown-driven exit the run is over and
/// nobody is left to adopt them — skip the inbox churn).
fn run_worker_pooled(
    k: usize,
    mut source: WorkerSource,
    factory: EngineFactory,
    published: Arc<Published>,
    tx: mpsc::Sender<ToServer>,
    profile: WorkerProfile,
) {
    run_worker(k, &mut source, factory, published.clone(), tx, profile);
    if let WorkerSource::Pool(pool) = source {
        if !published.snapshot().2 {
            pool.surrender();
        }
    }
}

/// Spawn the evaluator thread: one trace row whenever the published
/// version has advanced, sampled at a wall-clock cadence.  Shared by
/// the in-process and networked coordinators.
fn spawn_evaluator<'scope>(
    scope: &'scope std::thread::Scope<'scope, '_>,
    published: std::sync::Arc<Published>,
    clock: Stopwatch,
    every_secs: f64,
    ef: EvalFactory,
) -> std::thread::ScopedJoinHandle<'scope, Vec<TraceRow>> {
    let every = every_secs.max(1e-3);
    scope.spawn(move || {
        let mut eval = ef();
        let mut trace: Vec<TraceRow> = Vec::new();
        let mut last_version = u64::MAX;
        loop {
            let (version, theta, shutdown) = published.snapshot();
            if version != last_version {
                let m = eval(version, &theta);
                trace.push(TraceRow {
                    t_secs: clock.secs(),
                    version,
                    rmse: m.rmse,
                    mnlp: m.mnlp,
                    neg_elbo: m.neg_elbo,
                });
                last_version = version;
            }
            if shutdown {
                return trace;
            }
            std::thread::sleep(Duration::from_secs_f64(every));
        }
    })
}

/// Spawn the wall-clock watchdog: past `limit` it shuts down **every**
/// handle in `all` (in a sharded run, each slice plus the assembled
/// view — one stuck slice must not outlive the limit).  `watch` (the
/// assembled/only view) is observed for the early-exit path.
fn spawn_watchdog<'scope>(
    scope: &'scope std::thread::Scope<'scope, '_>,
    watch: std::sync::Arc<Published>,
    all: Vec<std::sync::Arc<Published>>,
    clock: Stopwatch,
    limit: f64,
) -> std::thread::ScopedJoinHandle<'scope, ()> {
    scope.spawn(move || loop {
        if watch.snapshot().2 {
            return;
        }
        if clock.secs() > limit {
            for p in &all {
                p.shutdown();
            }
            return;
        }
        std::thread::sleep(Duration::from_millis(20));
    })
}

/// The full-control entry point: caller-owned [`Published`] handle,
/// arbitrary worker sources, and late [`Joiner`]s.  Every other train
/// function is a thin wrapper over this.  With
/// [`TrainConfig::servers`] > 1 the run transparently uses the
/// partitioned topology (the caller's handle becomes the assembled
/// view).
pub fn train_elastic(
    cfg: &TrainConfig,
    published: std::sync::Arc<Published>,
    sources: Vec<WorkerSource>,
    joiners: Vec<Joiner>,
    factory: EngineFactory,
    eval_factory: Option<EvalFactory>,
) -> RunResult {
    // Install the run's compute backend before any worker/evaluator
    // thread constructs an engine (warn-and-fall-back: this entry
    // point has no error channel, and scalar is always safe).
    backend::activate(cfg.backend);
    if cfg.servers > 1 {
        return train_elastic_sharded(cfg, published, sources, joiners, factory, eval_factory);
    }
    let clock = Stopwatch::start();
    let workers = sources.len();
    assert!(workers >= 1, "need at least one initial worker source");
    if let Some(ck) = &cfg.resume_from {
        check_resume_layout(ck, &cfg.layout);
        // Restore the published state *before* any worker or evaluator
        // starts: the first θ anyone observes is the checkpointed θ, at
        // the checkpointed version.
        published.publish(ck.version, ck.theta.clone());
    }
    let (tx, rx) = mpsc::channel::<ToServer>();
    let mut server_cfg = server_config(cfg, workers, joiners.len());
    let mut profiles = resolve_profiles(cfg, workers);
    // ---- stream cursors + corruption quarantine (ISSUE 7) ----
    let (cursors, quarantine) = wire_store_robustness(cfg, &mut profiles);
    server_cfg.cursors = Some(cursors.clone());
    server_cfg.store_quarantines = Some(quarantine.counter.clone());
    // One shard inbox per run: departed pool workers surrender their
    // out-of-core shards here, survivors adopt them (ISSUE 6).
    let inbox: ShardInbox = Arc::new(Mutex::new(Vec::new()));

    std::thread::scope(|scope| {
        // ---- initial workers ----
        for ((k, source), profile) in sources.into_iter().enumerate().zip(profiles) {
            let factory = factory.clone();
            let published = published.clone();
            let tx = tx.clone();
            let mut source = pool_source(k, source, &inbox);
            source.set_fault_policy(quarantine.clone());
            scope.spawn(move || run_worker_pooled(k, source, factory, published, tx, profile));
        }
        // ---- late joiners (ids continue after the initial workers) ----
        for (j, joiner) in joiners.into_iter().enumerate() {
            let k = workers + j;
            let factory = factory.clone();
            let published = published.clone();
            let tx = tx.clone();
            let Joiner { after, source, mut profile } = joiner;
            profile.cursors = Some(cursors.clone());
            let mut source = pool_source(k, source, &inbox);
            source.set_fault_policy(quarantine.clone());
            scope.spawn(move || {
                // Interruptible delay: a run that ends early (time
                // limit, max_updates) wakes this immediately instead of
                // holding train_elastic open for the full join delay.
                if published.shutdown_or_timeout(after) {
                    return; // run already over; never joined
                }
                run_worker_pooled(k, source, factory, published, tx, profile)
            });
        }
        drop(tx); // server's recv() unblocks when all workers exit

        // ---- evaluator ----
        let trace_handle = eval_factory.map(|ef| {
            spawn_evaluator(scope, published.clone(), clock, cfg.eval_every_secs, ef)
        });

        // ---- watchdog for the wall-clock limit ----
        let watchdog = cfg.time_limit_secs.map(|limit| {
            spawn_watchdog(scope, published.clone(), vec![published.clone()], clock, limit)
        });

        // ---- server (on this thread) ----
        let outcome = run_server(&server_cfg, published.clone(), rx);
        published.shutdown();
        let trace = trace_handle
            .map(|h| h.join().expect("evaluator panicked"))
            .unwrap_or_default();
        if let Some(w) = watchdog {
            let _ = w.join();
        }
        record_lineage(cfg, outcome.stats.updates, clock.secs());
        RunResult {
            theta: outcome.theta,
            trace,
            stats: outcome.stats,
            wall_secs: clock.secs(),
        }
    })
}

/// The in-process partitioned topology (ISSUE 5): `cfg.servers` slice
/// server loops, each owning a contiguous θ range with its own
/// [`super::DelayGate`], optimizer state, and per-slice checkpoints;
/// one assembler presenting workers/evaluator/watchdog the full-θ view
/// (the caller's `published` handle); one splitter fanning each worker
/// gradient out per slice.  Worker math, elasticity, and the τ=0
/// bitwise guarantee are unchanged from the single-server path.
fn train_elastic_sharded(
    cfg: &TrainConfig,
    published: std::sync::Arc<Published>,
    sources: Vec<WorkerSource>,
    joiners: Vec<Joiner>,
    factory: EngineFactory,
    eval_factory: Option<EvalFactory>,
) -> RunResult {
    let clock = Stopwatch::start();
    let workers = sources.len();
    assert!(workers >= 1, "need at least one initial worker source");
    let topo = Topology::partition(cfg.layout.len(), cfg.servers);
    if let Some(ck) = &cfg.resume_from {
        check_resume_layout(ck, &cfg.layout);
        published.publish(ck.version, ck.theta.clone());
    }
    // Seed the slice views from the (possibly resumed) assembled state.
    let theta_now = published.snapshot().1;
    let sharded = ShardedPublished::new(topo.clone(), &theta_now, published.clone());
    if let Some(ck) = &cfg.resume_from {
        sharded.seed(ck.version, &ck.theta);
    }
    let ck_dirs = sharded_checkpoint_dirs(cfg, &topo);
    let expected_joiners = joiners.len();
    let mut profiles = resolve_profiles(cfg, workers);
    // ---- stream cursors + corruption quarantine (ISSUE 7) ----
    let (cursors, quarantine) = wire_store_robustness(cfg, &mut profiles);
    let inbox: ShardInbox = Arc::new(Mutex::new(Vec::new()));

    let (tx_all, rx_all) = mpsc::channel::<ToServer>();
    let mut slice_txs = Vec::with_capacity(topo.n_slices());
    let mut slice_rxs = Vec::with_capacity(topo.n_slices());
    for _ in 0..topo.n_slices() {
        let (t, r) = mpsc::channel::<ToServer>();
        slice_txs.push(t);
        slice_rxs.push(r);
    }

    std::thread::scope(|scope| {
        // ---- splitter: merged worker channel → per-slice channels ----
        {
            let topo = topo.clone();
            scope.spawn(move || run_splitter(&topo, rx_all, slice_txs));
        }
        // ---- assembler: slice views → the caller's assembled view ----
        {
            let sharded_ref = &sharded;
            scope.spawn(move || run_assembler(sharded_ref));
        }
        // ---- workers (on the assembled view, splitter channel) ----
        for ((k, source), profile) in sources.into_iter().enumerate().zip(profiles) {
            let factory = factory.clone();
            let published = published.clone();
            let tx = tx_all.clone();
            let mut source = pool_source(k, source, &inbox);
            source.set_fault_policy(quarantine.clone());
            scope.spawn(move || run_worker_pooled(k, source, factory, published, tx, profile));
        }
        for (j, joiner) in joiners.into_iter().enumerate() {
            let k = workers + j;
            let factory = factory.clone();
            let published = published.clone();
            let tx = tx_all.clone();
            let Joiner { after, source, mut profile } = joiner;
            profile.cursors = Some(cursors.clone());
            let mut source = pool_source(k, source, &inbox);
            source.set_fault_policy(quarantine.clone());
            scope.spawn(move || {
                if published.shutdown_or_timeout(after) {
                    return;
                }
                run_worker_pooled(k, source, factory, published, tx, profile)
            });
        }
        drop(tx_all); // splitter (and so every slice server) unblocks when workers exit

        let trace_handle = eval_factory.map(|ef| {
            spawn_evaluator(scope, published.clone(), clock, cfg.eval_every_secs, ef)
        });
        let watchdog = cfg.time_limit_secs.map(|limit| {
            let mut all: Vec<std::sync::Arc<Published>> = sharded.slices.clone();
            all.push(published.clone());
            spawn_watchdog(scope, published.clone(), all, clock, limit)
        });

        // ---- slice servers (scoped threads; outcomes joined below) ----
        let server_handles: Vec<_> = slice_rxs
            .into_iter()
            .enumerate()
            .zip(ck_dirs)
            .map(|((i, rx), (dir, resume))| {
                let mut scfg = slice_server_config(
                    cfg,
                    workers,
                    expected_joiners,
                    topo.slice(i),
                    dir,
                    resume,
                );
                // Every slice snapshots the same registry (at τ=0 the
                // slices step in lockstep, so the snapshots agree and
                // `Checkpoint::assemble` takes slice 0's); the shared
                // quarantine counter goes to slice 0 only so
                // `merge_outcomes`' sum is the session count.
                scfg.cursors = Some(cursors.clone());
                if i == 0 {
                    scfg.store_quarantines = Some(quarantine.counter.clone());
                }
                let p = sharded.slices[i].clone();
                scope.spawn(move || run_server(&scfg, p, rx))
            })
            .collect();
        let outcomes: Vec<ServerOutcome> = server_handles
            .into_iter()
            .map(|h| h.join().expect("slice server panicked"))
            .collect();
        sharded.shutdown_all();
        let trace = trace_handle
            .map(|h| h.join().expect("evaluator panicked"))
            .unwrap_or_default();
        if let Some(w) = watchdog {
            let _ = w.join();
        }
        let merged = merge_outcomes(&topo, outcomes);
        record_lineage(cfg, merged.stats.updates, clock.secs());
        RunResult {
            theta: merged.theta,
            trace,
            stats: merged.stats,
            wall_secs: clock.secs(),
        }
    })
}

/// The networked transport's heartbeat window from the config.
fn heartbeat_of(cfg: &TrainConfig) -> Option<Duration> {
    (cfg.heartbeat_secs > 0.0).then(|| Duration::from_secs_f64(cfg.heartbeat_secs))
}

/// Serve a training run over the networked transport (ISSUE 4): the
/// server loop runs here, workers connect over TCP (`advgp worker
/// --connect`, [`super::net::remote_worker_loop`], or any
/// codec-compatible client) and stream pushes in while θ snapshots fan
/// out.  `workers` is the *expected* initial worker count — it sizes
/// the [`super::DelayGate`] exactly as the in-process paths do, so
/// update 0 waits for one gradient from each of the `workers` ids
/// `0..workers`; connections claiming ids beyond that are admitted as
/// elastic joiners on their first push.
///
/// Checkpointing, retention GC, resume, the evaluator, and the
/// wall-clock watchdog all behave exactly as in [`train_elastic`] —
/// they are server-side concerns the transport never sees.  At τ=0
/// (with deterministic engines and fixed per-worker thread budgets) a
/// loopback-TCP run reproduces the in-process θ trajectory bitwise
/// (pinned by `rust/tests/net_transport.rs`).
///
/// Returns when `max_updates` is reached, the wall-clock limit fires,
/// or every admitted worker has departed.
pub fn train_remote(
    cfg: &TrainConfig,
    theta0: Vec<f64>,
    net: super::net::NetServer,
    workers: usize,
    eval_factory: Option<EvalFactory>,
) -> RunResult {
    backend::activate(cfg.backend);
    let clock = Stopwatch::start();
    assert!(workers >= 1, "need at least one expected worker");
    assert_eq!(theta0.len(), cfg.layout.len(), "θ₀ does not match the layout");
    let published = Published::new(theta0);
    if let Some(ck) = &cfg.resume_from {
        check_resume_layout(ck, &cfg.layout);
        // Before the listener starts accepting: the first θ any remote
        // worker handshakes onto is the checkpointed θ.
        published.publish(ck.version, ck.theta.clone());
    }
    let (tx, rx) = mpsc::channel::<ToServer>();
    let mut server_cfg = server_config(cfg, workers, 0);
    // Transport-fault counter (ISSUE 6): the accept loop's connection
    // handlers bump it, the server loop samples it into
    // [`ServerStats::faults`](super::metrics::ServerStats) at teardown.
    let faults = Arc::new(AtomicU64::new(0));
    server_cfg.transport_faults = Some(faults.clone());
    let addr = net.local_addr();

    std::thread::scope(|scope| {
        // ---- transport: accept loop (reader/publisher threads per
        // connection are detached inside) ----
        {
            let published = published.clone();
            let mut opts = super::net::NetServeOpts::single(
                cfg.layout,
                cfg.tau,
                workers,
                heartbeat_of(cfg),
            );
            opts.faults = faults.clone();
            scope.spawn(move || super::net::accept_loop(net, published, tx, opts));
        }
        // (`tx` moved into the accept loop; per-connection readers hold
        // clones.  The server loop therefore ends via its membership /
        // max_updates / watchdog conditions, not channel disconnect.)

        let trace_handle = eval_factory.map(|ef| {
            spawn_evaluator(scope, published.clone(), clock, cfg.eval_every_secs, ef)
        });
        let watchdog = cfg.time_limit_secs.map(|limit| {
            spawn_watchdog(scope, published.clone(), vec![published.clone()], clock, limit)
        });

        // ---- server (on this thread) ----
        let outcome = run_server(&server_cfg, published.clone(), rx);
        published.shutdown();
        // Unblock the accept loop so the scope can close.
        super::net::wake(addr);
        let trace = trace_handle
            .map(|h| h.join().expect("evaluator panicked"))
            .unwrap_or_default();
        if let Some(w) = watchdog {
            let _ = w.join();
        }
        record_lineage(cfg, outcome.stats.updates, clock.secs());
        RunResult {
            theta: outcome.theta,
            trace,
            stats: outcome.stats,
            wall_secs: clock.secs(),
        }
    })
}

/// Serve a **partitioned** training run over TCP (ISSUE 5): one slice
/// server per listener in `nets` (the partition is
/// `Topology::partition(dim, nets.len())`, in listener order), all in
/// this process.  Workers connect to *every* listener
/// ([`super::net::sharded_worker_loop`] / `advgp worker --connect
/// a0,a1,…`); the evaluator and watchdog run on the assembled view.
/// Checkpoints are per-slice under `checkpoint_dir/slice_*/` with a
/// topology manifest at the root; [`Checkpoint::load_latest_any`]
/// reassembles them for `resume_from`.
pub fn train_remote_sharded(
    cfg: &TrainConfig,
    theta0: Vec<f64>,
    nets: Vec<super::net::NetServer>,
    workers: usize,
    eval_factory: Option<EvalFactory>,
) -> RunResult {
    backend::activate(cfg.backend);
    let clock = Stopwatch::start();
    assert!(workers >= 1, "need at least one expected worker");
    assert!(!nets.is_empty(), "need at least one listener");
    assert_eq!(theta0.len(), cfg.layout.len(), "θ₀ does not match the layout");
    let topo = Topology::partition(cfg.layout.len(), nets.len());
    let published = Published::new(theta0.clone());
    if let Some(ck) = &cfg.resume_from {
        check_resume_layout(ck, &cfg.layout);
        published.publish(ck.version, ck.theta.clone());
    }
    let sharded = ShardedPublished::new(topo.clone(), &theta0, published.clone());
    if let Some(ck) = &cfg.resume_from {
        sharded.seed(ck.version, &ck.theta);
    }
    let ck_dirs = sharded_checkpoint_dirs(cfg, &topo);
    let addrs: Vec<std::net::SocketAddr> = nets.iter().map(|n| n.local_addr()).collect();
    let heartbeat = heartbeat_of(cfg);

    std::thread::scope(|scope| {
        // ---- one accept loop + server loop per slice ----
        let mut server_handles = Vec::with_capacity(topo.n_slices());
        for ((i, net), (dir, resume)) in nets.into_iter().enumerate().zip(ck_dirs) {
            let (tx, rx) = mpsc::channel::<ToServer>();
            let slice_pub = sharded.slices[i].clone();
            // Per-slice fault counter: each listener owns disjoint
            // connections, so [`merge_outcomes`] can sum them.
            let faults = Arc::new(AtomicU64::new(0));
            {
                let slice_pub = slice_pub.clone();
                let opts = super::net::NetServeOpts {
                    layout: cfg.layout,
                    tau: cfg.tau,
                    declared_workers: workers,
                    slice: topo.slice(i),
                    topology: topo.clone(),
                    heartbeat,
                    retry: super::net::RetryPolicy::default(),
                    faults: faults.clone(),
                };
                scope.spawn(move || super::net::accept_loop(net, slice_pub, tx, opts));
            }
            let mut scfg = slice_server_config(cfg, workers, 0, topo.slice(i), dir, resume);
            scfg.transport_faults = Some(faults);
            server_handles.push(scope.spawn(move || run_server(&scfg, slice_pub, rx)));
        }
        // ---- assembler for the evaluator/watchdog view ----
        {
            let sharded_ref = &sharded;
            scope.spawn(move || run_assembler(sharded_ref));
        }
        let trace_handle = eval_factory.map(|ef| {
            spawn_evaluator(scope, published.clone(), clock, cfg.eval_every_secs, ef)
        });
        let watchdog = cfg.time_limit_secs.map(|limit| {
            let mut all: Vec<std::sync::Arc<Published>> = sharded.slices.clone();
            all.push(published.clone());
            spawn_watchdog(scope, published.clone(), all, clock, limit)
        });

        let outcomes: Vec<ServerOutcome> = server_handles
            .into_iter()
            .map(|h| h.join().expect("slice server panicked"))
            .collect();
        sharded.shutdown_all();
        for a in &addrs {
            super::net::wake(*a);
        }
        let trace = trace_handle
            .map(|h| h.join().expect("evaluator panicked"))
            .unwrap_or_default();
        if let Some(w) = watchdog {
            let _ = w.join();
        }
        let merged = merge_outcomes(&topo, outcomes);
        record_lineage(cfg, merged.stats.updates, clock.secs());
        RunResult {
            theta: merged.theta,
            trace,
            stats: merged.stats,
            wall_secs: clock.secs(),
        }
    })
}

/// Serve exactly **one** θ slice of a partitioned run (ISSUE 5, the
/// multi-process deployment: `advgp serve-ps --slice i/S` — every slice
/// in its own process, no process holding all of θ).  `theta0` is the
/// *full* seed vector (every slice process derives its share from the
/// shared seed); `cfg.resume_from`, if set, is likewise the assembled
/// checkpoint ([`Checkpoint::load_latest_any`]) and is re-sliced here.
///
/// No evaluator runs — this process never sees the other slices, so
/// there is no full θ to evaluate; drive evaluation from a worker-side
/// observer or a single-process [`train_remote_sharded`] instead.  The
/// returned `theta` is this slice's final fragment.  Lineage is
/// recorded by slice 0 only (one writer per manifest).
pub fn train_remote_slice(
    cfg: &TrainConfig,
    theta0: Vec<f64>,
    net: super::net::NetServer,
    workers: usize,
    slice_id: usize,
    n_slices: usize,
) -> RunResult {
    backend::activate(cfg.backend);
    let clock = Stopwatch::start();
    assert!(workers >= 1, "need at least one expected worker");
    assert_eq!(theta0.len(), cfg.layout.len(), "θ₀ does not match the layout");
    assert!(slice_id < n_slices, "--slice {slice_id}/{n_slices} out of range");
    let topo = Topology::partition(cfg.layout.len(), n_slices);
    let slice = topo.slice(slice_id);
    let published = Published::new(theta0[slice.range.clone()].to_vec());
    let resume = cfg.resume_from.as_ref().map(|ck| {
        check_resume_layout(ck, &cfg.layout);
        ck.slice_of(slice.range.clone())
    });
    if let Some(ck) = &resume {
        published.publish(ck.version, ck.theta.clone());
    }
    let ck_dir = cfg.checkpoint_dir.as_ref().map(|root| {
        if cfg.checkpoint_every > 0 {
            ensure_topology_manifest(root, cfg.layout, &topo);
        }
        Checkpoint::slice_dir(root, slice_id, n_slices)
    });
    let (tx, rx) = mpsc::channel::<ToServer>();
    let mut scfg = slice_server_config(cfg, workers, 0, slice.clone(), ck_dir, resume);
    let faults = Arc::new(AtomicU64::new(0));
    scfg.transport_faults = Some(faults.clone());
    let addr = net.local_addr();

    std::thread::scope(|scope| {
        {
            let published = published.clone();
            let opts = super::net::NetServeOpts {
                layout: cfg.layout,
                tau: cfg.tau,
                declared_workers: workers,
                slice: slice.clone(),
                topology: topo.clone(),
                heartbeat: heartbeat_of(cfg),
                retry: super::net::RetryPolicy::default(),
                faults: faults.clone(),
            };
            scope.spawn(move || super::net::accept_loop(net, published, tx, opts));
        }
        let watchdog = cfg.time_limit_secs.map(|limit| {
            spawn_watchdog(scope, published.clone(), vec![published.clone()], clock, limit)
        });
        let outcome = run_server(&scfg, published.clone(), rx);
        published.shutdown();
        super::net::wake(addr);
        if let Some(w) = watchdog {
            let _ = w.join();
        }
        if slice_id == 0 {
            record_lineage(cfg, outcome.stats.updates, clock.secs());
        }
        RunResult {
            theta: outcome.theta,
            trace: Vec::new(),
            stats: outcome.stats,
            wall_secs: clock.secs(),
        }
    })
}

/// Convenience: a native evaluator factory over a held-out set, with an
/// optional (x, y) subset for −ELBO tracking (Appendix C traces).
///
/// Runs on the serving stack: an internal `serve::PosteriorCache`
/// (rebuilt only when the published version advances) plus reusable
/// `PredictWorkspace`/output buffers, so a mid-training evaluation pass
/// allocates nothing beyond the per-version O(m³) factor build — the
/// pre-ISSUE-2 evaluator rebuilt the model *and* allocated fresh
/// buffers on every snapshot.
pub fn native_eval_factory(
    layout: ThetaLayout,
    test: Dataset,
    elbo_set: Option<Dataset>,
) -> EvalFactory {
    Box::new(move || {
        let cache = crate::serve::PosteriorCache::new(layout);
        let mut ws = crate::gp::PredictWorkspace::new();
        let mut mean: Vec<f64> = Vec::new();
        let mut var: Vec<f64> = Vec::new();
        Box::new(move |version: u64, theta: &[f64]| {
            cache.install(version, theta);
            let post = cache.get().expect("posterior installed");
            post.gp.predict_into(&test.x, &mut ws, &mut mean, &mut var);
            let rmse = crate::util::rmse(&mean, &test.y);
            let mnlp = crate::util::mnlp(&mean, &var, &test.y);
            let neg_elbo = elbo_set
                .as_ref()
                .map(|es| post.gp.neg_elbo_ws(&es.x, &es.y, &mut ws));
            EvalMetrics { rmse, mnlp, neg_elbo }
        })
    })
}
