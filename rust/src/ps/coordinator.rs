//! Wiring: spawn server + workers (+ late joiners) + evaluator, run to
//! completion, collect traces.  This is the entry point every
//! experiment uses.

use super::checkpoint::Checkpoint;
use super::messages::ToServer;
use super::metrics::{EvalMetrics, ServerStats, TraceRow};
use super::server::{run_server, ServerConfig};
use super::worker::{run_worker, WorkerProfile, WorkerSource};
use super::Published;
use crate::data::Dataset;
use crate::gp::ThetaLayout;
use crate::grad::EngineFactory;
use crate::opt::StepSchedule;
use crate::util::Stopwatch;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Duration;

/// Evaluation closure, constructed *inside* the evaluator thread
/// (PJRT evaluators are not Send).  Called as `(version, θ)` so the
/// evaluator can key posterior caches by the published version.
pub type EvalFactory =
    Box<dyn FnOnce() -> Box<dyn FnMut(u64, &[f64]) -> EvalMetrics> + Send>;

pub struct TrainConfig {
    pub layout: ThetaLayout,
    pub tau: u64,
    /// Cumulative published-version ceiling — see
    /// [`ServerConfig::max_updates`](super::server::ServerConfig).
    pub max_updates: u64,
    /// Learning-rate scale on the ADADELTA direction (paper §6.1).
    pub lr: f64,
    /// Proximal strength γ_t schedule.
    pub prox: StepSchedule,
    pub server_shards: usize,
    pub freeze_hyper: bool,
    /// Per-worker behaviour; padded with defaults if shorter than the
    /// number of shards.
    pub profiles: Vec<WorkerProfile>,
    /// Evaluator cadence (seconds). 0 disables intermediate snapshots.
    pub eval_every_secs: f64,
    /// Hard wall-clock limit; the run is shut down when exceeded.
    pub time_limit_secs: Option<f64>,
    /// Thread-pool budget per worker for its gradient linalg
    /// (0 = auto: `util::pool::threads()` split evenly across workers,
    /// min 1).  Individual `WorkerProfile::threads` values override.
    pub worker_threads: usize,
    /// Write a server-state checkpoint every N updates into
    /// `checkpoint_dir` (0 = never).  See [`crate::ps::checkpoint`].
    pub checkpoint_every: u64,
    pub checkpoint_dir: Option<PathBuf>,
    /// Checkpoint retention: after every successful save keep only the
    /// newest K files in `checkpoint_dir` (`None` = keep all; clamped
    /// to ≥ 1 so the final seal always survives).  See
    /// [`Checkpoint::prune_keep_last`].
    pub keep_last: Option<usize>,
    /// Resume from a frozen server state (load it with
    /// [`Checkpoint::load`] / [`Checkpoint::load_latest`]): the run
    /// publishes `(ck.version, ck.θ)` before any worker starts, and θ,
    /// the version counter, and the ADADELTA accumulators restore
    /// bitwise.
    pub resume_from: Option<Checkpoint>,
}

impl TrainConfig {
    pub fn new(layout: ThetaLayout) -> Self {
        Self {
            layout,
            tau: 32, // the paper's tuned default for the flight runs
            max_updates: 500,
            lr: 1.0,
            prox: StepSchedule::new(0.05, 200.0),
            server_shards: 1,
            freeze_hyper: false,
            profiles: vec![],
            eval_every_secs: 0.5,
            time_limit_secs: None,
            worker_threads: 0,
            checkpoint_every: 0,
            checkpoint_dir: None,
            keep_last: None,
            resume_from: None,
        }
    }
}

/// A worker that enters the run late (ISSUE 3 elasticity): after
/// `after`, it snapshots the live published version — *adopting* the
/// current θ — and joins the push/pull loop.  The server admits it on
/// its first push.
pub struct Joiner {
    pub after: Duration,
    pub source: WorkerSource,
    pub profile: WorkerProfile,
}

pub struct RunResult {
    pub theta: Vec<f64>,
    pub trace: Vec<TraceRow>,
    pub stats: ServerStats,
    pub wall_secs: f64,
}

/// Train ADVGP: Algorithm 1 end-to-end over the given resident shards.
pub fn train(
    cfg: &TrainConfig,
    theta0: Vec<f64>,
    shards: Vec<Dataset>,
    factory: EngineFactory,
    eval_factory: Option<EvalFactory>,
) -> RunResult {
    let sources = shards.into_iter().map(WorkerSource::Memory).collect();
    train_elastic(cfg, Published::new(theta0), sources, vec![], factory, eval_factory)
}

/// [`train`] over arbitrary worker data sources — resident datasets or
/// out-of-core [`crate::data::store::ShardReader`]s (typically a
/// [`crate::data::store::ShardSet`]'s readers).
pub fn train_sources(
    cfg: &TrainConfig,
    theta0: Vec<f64>,
    sources: Vec<WorkerSource>,
    factory: EngineFactory,
    eval_factory: Option<EvalFactory>,
) -> RunResult {
    train_elastic(cfg, Published::new(theta0), sources, vec![], factory, eval_factory)
}

/// [`train`] against a caller-owned [`Published`] handle (seeded with
/// θ₀).  This lets a serving stack — e.g. a `serve::BatchServer`
/// syncing its `PosteriorCache` — follow the live θ *while training
/// runs* (see `examples/serve_latency.rs`); `train` is the
/// convenience wrapper that creates the handle itself.
pub fn train_published(
    cfg: &TrainConfig,
    published: std::sync::Arc<Published>,
    shards: Vec<Dataset>,
    factory: EngineFactory,
    eval_factory: Option<EvalFactory>,
) -> RunResult {
    let sources = shards.into_iter().map(WorkerSource::Memory).collect();
    train_elastic(cfg, published, sources, vec![], factory, eval_factory)
}

/// Layout guard shared by every resume path: compare (m, d), not just
/// θ length — distinct layouts can collide on dimension (e.g. m=1,d=5
/// and m=2,d=2 both give 14), and restoring across that collision would
/// silently slice every θ block at the wrong offsets.
fn check_resume_layout(ck: &Checkpoint, layout: &ThetaLayout) {
    assert_eq!(
        (ck.m, ck.d),
        (layout.m, layout.d),
        "resume checkpoint is for layout m={}, d={} but this run uses \
         m={}, d={}",
        ck.m,
        ck.d,
        layout.m,
        layout.d
    );
}

/// Lower a [`TrainConfig`] into the server loop's own config.
fn server_config(cfg: &TrainConfig, workers: usize, expected_joiners: usize) -> ServerConfig {
    ServerConfig {
        layout: cfg.layout,
        workers,
        tau: cfg.tau,
        max_updates: cfg.max_updates,
        lr: cfg.lr,
        prox: cfg.prox,
        server_shards: cfg.server_shards,
        freeze_hyper: cfg.freeze_hyper,
        checkpoint_every: cfg.checkpoint_every,
        checkpoint_dir: cfg.checkpoint_dir.clone(),
        keep_last: cfg.keep_last,
        resume: cfg.resume_from.clone(),
        expected_joiners,
    }
}

/// Spawn the evaluator thread: one trace row whenever the published
/// version has advanced, sampled at a wall-clock cadence.  Shared by
/// the in-process and networked coordinators.
fn spawn_evaluator<'scope>(
    scope: &'scope std::thread::Scope<'scope, '_>,
    published: std::sync::Arc<Published>,
    clock: Stopwatch,
    every_secs: f64,
    ef: EvalFactory,
) -> std::thread::ScopedJoinHandle<'scope, Vec<TraceRow>> {
    let every = every_secs.max(1e-3);
    scope.spawn(move || {
        let mut eval = ef();
        let mut trace: Vec<TraceRow> = Vec::new();
        let mut last_version = u64::MAX;
        loop {
            let (version, theta, shutdown) = published.snapshot();
            if version != last_version {
                let m = eval(version, &theta);
                trace.push(TraceRow {
                    t_secs: clock.secs(),
                    version,
                    rmse: m.rmse,
                    mnlp: m.mnlp,
                    neg_elbo: m.neg_elbo,
                });
                last_version = version;
            }
            if shutdown {
                return trace;
            }
            std::thread::sleep(Duration::from_secs_f64(every));
        }
    })
}

/// Spawn the wall-clock watchdog: shuts the run down past `limit`.
fn spawn_watchdog<'scope>(
    scope: &'scope std::thread::Scope<'scope, '_>,
    published: std::sync::Arc<Published>,
    clock: Stopwatch,
    limit: f64,
) -> std::thread::ScopedJoinHandle<'scope, ()> {
    scope.spawn(move || loop {
        if published.snapshot().2 {
            return;
        }
        if clock.secs() > limit {
            published.shutdown();
            return;
        }
        std::thread::sleep(Duration::from_millis(20));
    })
}

/// The full-control entry point: caller-owned [`Published`] handle,
/// arbitrary worker sources, and late [`Joiner`]s.  Every other train
/// function is a thin wrapper over this.
pub fn train_elastic(
    cfg: &TrainConfig,
    published: std::sync::Arc<Published>,
    sources: Vec<WorkerSource>,
    joiners: Vec<Joiner>,
    factory: EngineFactory,
    eval_factory: Option<EvalFactory>,
) -> RunResult {
    let clock = Stopwatch::start();
    let workers = sources.len();
    assert!(workers >= 1, "need at least one initial worker source");
    if let Some(ck) = &cfg.resume_from {
        check_resume_layout(ck, &cfg.layout);
        // Restore the published state *before* any worker or evaluator
        // starts: the first θ anyone observes is the checkpointed θ, at
        // the checkpointed version.
        published.publish(ck.version, ck.theta.clone());
    }
    let (tx, rx) = mpsc::channel::<ToServer>();
    let server_cfg = server_config(cfg, workers, joiners.len());

    // Per-worker thread budgets.  Explicit budgets (profile or
    // cfg.worker_threads) are honored as-is; the remaining pool
    // capacity is split across the auto workers with the remainder
    // distributed one-by-one, so no core is left permanently idle by
    // integer truncation and explicit budgets aren't double-counted.
    // (Joiners keep their own profile budgets: honored as-is, min 1.)
    let mut profiles: Vec<WorkerProfile> = (0..workers)
        .map(|k| cfg.profiles.get(k).cloned().unwrap_or_default())
        .collect();
    if cfg.worker_threads > 0 {
        for p in profiles.iter_mut().filter(|p| p.threads == 0) {
            p.threads = cfg.worker_threads;
        }
    }
    let explicit: usize = profiles.iter().map(|p| p.threads).sum();
    let auto_count = profiles.iter().filter(|p| p.threads == 0).count();
    if auto_count > 0 {
        let avail = crate::util::pool::threads()
            .saturating_sub(explicit)
            .max(auto_count); // every worker gets at least one lane
        let base = avail / auto_count;
        let extra = avail % auto_count;
        for (i, p) in profiles.iter_mut().filter(|p| p.threads == 0).enumerate() {
            p.threads = (base + usize::from(i < extra)).max(1);
        }
    }

    std::thread::scope(|scope| {
        // ---- initial workers ----
        for ((k, source), profile) in sources.into_iter().enumerate().zip(profiles) {
            let factory = factory.clone();
            let published = published.clone();
            let tx = tx.clone();
            scope.spawn(move || {
                run_worker(k, source, factory, published, tx, profile)
            });
        }
        // ---- late joiners (ids continue after the initial workers) ----
        for (j, joiner) in joiners.into_iter().enumerate() {
            let k = workers + j;
            let factory = factory.clone();
            let published = published.clone();
            let tx = tx.clone();
            scope.spawn(move || {
                // Interruptible delay: a run that ends early (time
                // limit, max_updates) wakes this immediately instead of
                // holding train_elastic open for the full join delay.
                if published.shutdown_or_timeout(joiner.after) {
                    return; // run already over; never joined
                }
                run_worker(k, joiner.source, factory, published, tx, joiner.profile)
            });
        }
        drop(tx); // server's recv() unblocks when all workers exit

        // ---- evaluator ----
        let trace_handle = eval_factory.map(|ef| {
            spawn_evaluator(scope, published.clone(), clock, cfg.eval_every_secs, ef)
        });

        // ---- watchdog for the wall-clock limit ----
        let watchdog = cfg
            .time_limit_secs
            .map(|limit| spawn_watchdog(scope, published.clone(), clock, limit));

        // ---- server (on this thread) ----
        let outcome = run_server(&server_cfg, published.clone(), rx);
        published.shutdown();
        let trace = trace_handle
            .map(|h| h.join().expect("evaluator panicked"))
            .unwrap_or_default();
        if let Some(w) = watchdog {
            let _ = w.join();
        }
        RunResult {
            theta: outcome.theta,
            trace,
            stats: outcome.stats,
            wall_secs: clock.secs(),
        }
    })
}

/// Serve a training run over the `ADVGPNT1` networked transport
/// (ISSUE 4): the server loop runs here, workers connect over TCP
/// (`advgp worker --connect`, [`super::net::remote_worker_loop`], or
/// any codec-compatible client) and stream pushes in while θ snapshots
/// fan out.  `workers` is the *expected* initial worker count — it
/// sizes the [`super::DelayGate`] exactly as the in-process paths do,
/// so update 0 waits for one gradient from each of the `workers` ids
/// `0..workers`; connections claiming ids beyond that are admitted as
/// elastic joiners on their first push.
///
/// Checkpointing, retention GC, resume, the evaluator, and the
/// wall-clock watchdog all behave exactly as in [`train_elastic`] —
/// they are server-side concerns the transport never sees.  At τ=0
/// (with deterministic engines and fixed per-worker thread budgets) a
/// loopback-TCP run reproduces the in-process θ trajectory bitwise
/// (pinned by `rust/tests/net_transport.rs`).
///
/// Returns when `max_updates` is reached, the wall-clock limit fires,
/// or every admitted worker has departed.
pub fn train_remote(
    cfg: &TrainConfig,
    theta0: Vec<f64>,
    net: super::net::NetServer,
    workers: usize,
    eval_factory: Option<EvalFactory>,
) -> RunResult {
    let clock = Stopwatch::start();
    assert!(workers >= 1, "need at least one expected worker");
    assert_eq!(theta0.len(), cfg.layout.len(), "θ₀ does not match the layout");
    let published = Published::new(theta0);
    if let Some(ck) = &cfg.resume_from {
        check_resume_layout(ck, &cfg.layout);
        // Before the listener starts accepting: the first θ any remote
        // worker handshakes onto is the checkpointed θ.
        published.publish(ck.version, ck.theta.clone());
    }
    let (tx, rx) = mpsc::channel::<ToServer>();
    let server_cfg = server_config(cfg, workers, 0);
    let addr = net.local_addr();

    std::thread::scope(|scope| {
        // ---- transport: accept loop (reader/publisher threads per
        // connection are detached inside) ----
        {
            let published = published.clone();
            let layout = cfg.layout;
            let tau = cfg.tau;
            scope.spawn(move || {
                super::net::accept_loop(net, published, tx, layout, tau, workers)
            });
        }
        // (`tx` moved into the accept loop; per-connection readers hold
        // clones.  The server loop therefore ends via its membership /
        // max_updates / watchdog conditions, not channel disconnect.)

        let trace_handle = eval_factory.map(|ef| {
            spawn_evaluator(scope, published.clone(), clock, cfg.eval_every_secs, ef)
        });
        let watchdog = cfg
            .time_limit_secs
            .map(|limit| spawn_watchdog(scope, published.clone(), clock, limit));

        // ---- server (on this thread) ----
        let outcome = run_server(&server_cfg, published.clone(), rx);
        published.shutdown();
        // Unblock the accept loop so the scope can close.
        super::net::wake(addr);
        let trace = trace_handle
            .map(|h| h.join().expect("evaluator panicked"))
            .unwrap_or_default();
        if let Some(w) = watchdog {
            let _ = w.join();
        }
        RunResult {
            theta: outcome.theta,
            trace,
            stats: outcome.stats,
            wall_secs: clock.secs(),
        }
    })
}

/// Convenience: a native evaluator factory over a held-out set, with an
/// optional (x, y) subset for −ELBO tracking (Appendix C traces).
///
/// Runs on the serving stack: an internal `serve::PosteriorCache`
/// (rebuilt only when the published version advances) plus reusable
/// `PredictWorkspace`/output buffers, so a mid-training evaluation pass
/// allocates nothing beyond the per-version O(m³) factor build — the
/// pre-ISSUE-2 evaluator rebuilt the model *and* allocated fresh
/// buffers on every snapshot.
pub fn native_eval_factory(
    layout: ThetaLayout,
    test: Dataset,
    elbo_set: Option<Dataset>,
) -> EvalFactory {
    Box::new(move || {
        let cache = crate::serve::PosteriorCache::new(layout);
        let mut ws = crate::gp::PredictWorkspace::new();
        let mut mean: Vec<f64> = Vec::new();
        let mut var: Vec<f64> = Vec::new();
        Box::new(move |version: u64, theta: &[f64]| {
            cache.install(version, theta);
            let post = cache.get().expect("posterior installed");
            post.gp.predict_into(&test.x, &mut ws, &mut mean, &mut var);
            let rmse = crate::util::rmse(&mean, &test.y);
            let mnlp = crate::util::mnlp(&mean, &var, &test.y);
            let neg_elbo = elbo_set
                .as_ref()
                .map(|es| post.gp.neg_elbo_ws(&es.x, &es.y, &mut ws));
            EvalMetrics { rmse, mnlp, neg_elbo }
        })
    })
}
