//! Wiring: spawn server + workers + evaluator, run to completion,
//! collect traces.  This is the entry point every experiment uses.

use super::messages::ToServer;
use super::metrics::{EvalMetrics, ServerStats, TraceRow};
use super::server::{run_server, ServerConfig};
use super::worker::{run_worker, WorkerProfile};
use super::Published;
use crate::data::Dataset;
use crate::gp::ThetaLayout;
use crate::grad::EngineFactory;
use crate::opt::StepSchedule;
use crate::util::Stopwatch;
use std::sync::mpsc;
use std::time::Duration;

/// Evaluation closure, constructed *inside* the evaluator thread
/// (PJRT evaluators are not Send).  Called as `(version, θ)` so the
/// evaluator can key posterior caches by the published version.
pub type EvalFactory =
    Box<dyn FnOnce() -> Box<dyn FnMut(u64, &[f64]) -> EvalMetrics> + Send>;

pub struct TrainConfig {
    pub layout: ThetaLayout,
    pub tau: u64,
    pub max_updates: u64,
    /// Learning-rate scale on the ADADELTA direction (paper §6.1).
    pub lr: f64,
    /// Proximal strength γ_t schedule.
    pub prox: StepSchedule,
    pub server_shards: usize,
    pub freeze_hyper: bool,
    /// Per-worker behaviour; padded with defaults if shorter than the
    /// number of shards.
    pub profiles: Vec<WorkerProfile>,
    /// Evaluator cadence (seconds). 0 disables intermediate snapshots.
    pub eval_every_secs: f64,
    /// Hard wall-clock limit; the run is shut down when exceeded.
    pub time_limit_secs: Option<f64>,
    /// Thread-pool budget per worker for its gradient linalg
    /// (0 = auto: `util::pool::threads()` split evenly across workers,
    /// min 1).  Individual `WorkerProfile::threads` values override.
    pub worker_threads: usize,
}

impl TrainConfig {
    pub fn new(layout: ThetaLayout) -> Self {
        Self {
            layout,
            tau: 32, // the paper's tuned default for the flight runs
            max_updates: 500,
            lr: 1.0,
            prox: StepSchedule::new(0.05, 200.0),
            server_shards: 1,
            freeze_hyper: false,
            profiles: vec![],
            eval_every_secs: 0.5,
            time_limit_secs: None,
            worker_threads: 0,
        }
    }
}

pub struct RunResult {
    pub theta: Vec<f64>,
    pub trace: Vec<TraceRow>,
    pub stats: ServerStats,
    pub wall_secs: f64,
}

/// Train ADVGP: Algorithm 1 end-to-end over the given shards.
pub fn train(
    cfg: &TrainConfig,
    theta0: Vec<f64>,
    shards: Vec<Dataset>,
    factory: EngineFactory,
    eval_factory: Option<EvalFactory>,
) -> RunResult {
    train_published(cfg, Published::new(theta0), shards, factory, eval_factory)
}

/// [`train`] against a caller-owned [`Published`] handle (seeded with
/// θ₀).  This lets a serving stack — e.g. a `serve::BatchServer`
/// syncing its `PosteriorCache` — follow the live θ *while training
/// runs* (see `examples/serve_latency.rs`); `train` is the
/// convenience wrapper that creates the handle itself.
pub fn train_published(
    cfg: &TrainConfig,
    published: std::sync::Arc<Published>,
    shards: Vec<Dataset>,
    factory: EngineFactory,
    eval_factory: Option<EvalFactory>,
) -> RunResult {
    let clock = Stopwatch::start();
    let workers = shards.len();
    assert!(workers >= 1, "need at least one shard");
    let (tx, rx) = mpsc::channel::<ToServer>();

    let server_cfg = ServerConfig {
        layout: cfg.layout,
        workers,
        tau: cfg.tau,
        max_updates: cfg.max_updates,
        lr: cfg.lr,
        prox: cfg.prox,
        server_shards: cfg.server_shards,
        freeze_hyper: cfg.freeze_hyper,
    };

    // Per-worker thread budgets.  Explicit budgets (profile or
    // cfg.worker_threads) are honored as-is; the remaining pool
    // capacity is split across the auto workers with the remainder
    // distributed one-by-one, so no core is left permanently idle by
    // integer truncation and explicit budgets aren't double-counted.
    let mut profiles: Vec<WorkerProfile> = (0..workers)
        .map(|k| cfg.profiles.get(k).cloned().unwrap_or_default())
        .collect();
    if cfg.worker_threads > 0 {
        for p in profiles.iter_mut().filter(|p| p.threads == 0) {
            p.threads = cfg.worker_threads;
        }
    }
    let explicit: usize = profiles.iter().map(|p| p.threads).sum();
    let auto_count = profiles.iter().filter(|p| p.threads == 0).count();
    if auto_count > 0 {
        let avail = crate::util::pool::threads()
            .saturating_sub(explicit)
            .max(auto_count); // every worker gets at least one lane
        let base = avail / auto_count;
        let extra = avail % auto_count;
        for (i, p) in profiles.iter_mut().filter(|p| p.threads == 0).enumerate() {
            p.threads = (base + usize::from(i < extra)).max(1);
        }
    }

    std::thread::scope(|scope| {
        // ---- workers ----
        for ((k, shard), profile) in shards.into_iter().enumerate().zip(profiles) {
            let factory = factory.clone();
            let published = published.clone();
            let tx = tx.clone();
            scope.spawn(move || {
                run_worker(k, shard, factory, published, tx, profile)
            });
        }
        drop(tx); // server's recv() unblocks when all workers exit

        // ---- evaluator ----
        let trace_handle = eval_factory.map(|ef| {
            let published = published.clone();
            let every = cfg.eval_every_secs.max(1e-3);
            scope.spawn(move || {
                let mut eval = ef();
                let mut trace: Vec<TraceRow> = Vec::new();
                let mut last_version = u64::MAX;
                loop {
                    let (version, theta, shutdown) = published.snapshot();
                    if version != last_version {
                        let m = eval(version, &theta);
                        trace.push(TraceRow {
                            t_secs: clock.secs(),
                            version,
                            rmse: m.rmse,
                            mnlp: m.mnlp,
                            neg_elbo: m.neg_elbo,
                        });
                        last_version = version;
                    }
                    if shutdown {
                        return trace;
                    }
                    std::thread::sleep(Duration::from_secs_f64(every));
                }
            })
        });

        // ---- watchdog for the wall-clock limit ----
        let watchdog = cfg.time_limit_secs.map(|limit| {
            let published = published.clone();
            scope.spawn(move || loop {
                if published.snapshot().2 {
                    return;
                }
                if clock.secs() > limit {
                    published.shutdown();
                    return;
                }
                std::thread::sleep(Duration::from_millis(20));
            })
        });

        // ---- server (on this thread) ----
        let outcome = run_server(&server_cfg, published.clone(), rx);
        published.shutdown();
        let trace = trace_handle
            .map(|h| h.join().expect("evaluator panicked"))
            .unwrap_or_default();
        if let Some(w) = watchdog {
            let _ = w.join();
        }
        RunResult {
            theta: outcome.theta,
            trace,
            stats: outcome.stats,
            wall_secs: clock.secs(),
        }
    })
}

/// Convenience: a native evaluator factory over a held-out set, with an
/// optional (x, y) subset for −ELBO tracking (Appendix C traces).
///
/// Runs on the serving stack: an internal `serve::PosteriorCache`
/// (rebuilt only when the published version advances) plus reusable
/// `PredictWorkspace`/output buffers, so a mid-training evaluation pass
/// allocates nothing beyond the per-version O(m³) factor build — the
/// pre-ISSUE-2 evaluator rebuilt the model *and* allocated fresh
/// buffers on every snapshot.
pub fn native_eval_factory(
    layout: ThetaLayout,
    test: Dataset,
    elbo_set: Option<Dataset>,
) -> EvalFactory {
    Box::new(move || {
        let cache = crate::serve::PosteriorCache::new(layout);
        let mut ws = crate::gp::PredictWorkspace::new();
        let mut mean: Vec<f64> = Vec::new();
        let mut var: Vec<f64> = Vec::new();
        Box::new(move |version: u64, theta: &[f64]| {
            cache.install(version, theta);
            let post = cache.get().expect("posterior installed");
            post.gp.predict_into(&test.x, &mut ws, &mut mean, &mut var);
            let rmse = crate::util::rmse(&mean, &test.y);
            let mnlp = crate::util::mnlp(&mean, &var, &test.y);
            let neg_elbo = elbo_set
                .as_ref()
                .map(|es| post.gp.neg_elbo_ws(&es.x, &es.y, &mut ws));
            EvalMetrics { rmse, mnlp, neg_elbo }
        })
    })
}
