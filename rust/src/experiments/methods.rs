//! Uniform method runners for the benches: every method takes a
//! [`super::Problem`], a wall-clock budget, and returns a trace.

use super::Problem;
use crate::baselines::distgp::{run_distgp_gd, run_distgp_lbfgs, DistGpConfig};
use crate::baselines::linear::{run_linear, LinearConfig};
use crate::baselines::mean::MeanPredictor;
use crate::baselines::svigp::{run_svigp, SvigpConfig};
use crate::baselines::BaselineResult;
use crate::data::store::ShardSet;
use crate::grad::{native_factory, EngineFactory};
use crate::ps::checkpoint::Checkpoint;
use crate::ps::coordinator::{
    native_eval_factory, train, train_sources, TrainConfig,
};
use crate::ps::metrics::TraceRow;
use crate::ps::worker::{WorkerProfile, WorkerSource};
use crate::runtime::Backend;
use anyhow::Result;
use std::path::PathBuf;
use std::time::Duration;

/// Options shared by the GP methods.
#[derive(Clone, Debug)]
pub struct MethodOpts {
    pub workers: usize,
    /// θ-slice server count for the advgp parameter server (ISSUE 5):
    /// 1 = single server; S > 1 partitions θ across S in-process slice
    /// server loops (τ=0 trajectories are bitwise-identical either way).
    pub servers: usize,
    pub tau: u64,
    pub budget_secs: f64,
    /// Per-worker straggler sleeps (ms), cycled (Fig. 2).
    pub straggle_ms: Vec<u64>,
    /// Cap on rows per worker iteration (0 = full shard).
    pub max_rows: usize,
    pub eval_every_secs: f64,
    pub track_elbo: bool,
    /// ADADELTA direction scale (server-side gradient step).
    pub lr: f64,
    /// Proximal strength schedule γ_t = prox_c / (1 + t / prox_t0).
    pub prox_c: f64,
    pub prox_t0: f64,
    /// Checkpoint cadence in server updates (0 = off) and destination.
    pub checkpoint_every: u64,
    pub checkpoint_dir: Option<PathBuf>,
    /// Checkpoint retention: keep only the newest K files (None = all).
    pub keep_last: Option<usize>,
    /// Resume the run from this frozen server state.
    pub resume_from: Option<Checkpoint>,
    /// Compute backend for the run (ISSUE 10); defaults to the
    /// `ADVGP_BACKEND` env selection (scalar when unset).
    pub backend: Backend,
}

impl Default for MethodOpts {
    fn default() -> Self {
        Self {
            workers: 4,
            servers: 1,
            tau: 32,
            budget_secs: 10.0,
            straggle_ms: vec![],
            max_rows: 0,
            eval_every_secs: 0.25,
            track_elbo: false,
            lr: 1.0,
            prox_c: 0.005,
            prox_t0: 500.0,
            checkpoint_every: 0,
            checkpoint_dir: None,
            keep_last: None,
            resume_from: None,
            backend: Backend::from_env(),
        }
    }
}

fn profiles(opts: &MethodOpts, workers: usize) -> Vec<WorkerProfile> {
    (0..workers)
        .map(|k| WorkerProfile {
            straggle: Duration::from_millis(
                *opts.straggle_ms.get(k % opts.straggle_ms.len().max(1)).unwrap_or(&0),
            ),
            max_rows: opts.max_rows,
            ..Default::default()
        })
        .collect()
}

fn train_config(p: &Problem, opts: &MethodOpts, workers: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new(p.layout);
    cfg.servers = opts.servers.max(1);
    cfg.tau = opts.tau;
    cfg.max_updates = u64::MAX / 2;
    cfg.time_limit_secs = Some(opts.budget_secs);
    cfg.eval_every_secs = opts.eval_every_secs;
    cfg.profiles = profiles(opts, workers);
    cfg.lr = opts.lr;
    cfg.prox = crate::opt::StepSchedule::new(opts.prox_c, opts.prox_t0);
    cfg.checkpoint_every = opts.checkpoint_every;
    cfg.checkpoint_dir = opts.checkpoint_dir.clone();
    cfg.keep_last = opts.keep_last;
    cfg.resume_from = opts.resume_from.clone();
    cfg.backend = opts.backend;
    cfg
}

/// ADVGP (the paper's method) with a pluggable engine factory.
pub fn run_advgp_with(
    p: &Problem,
    opts: &MethodOpts,
    factory: EngineFactory,
) -> BaselineResult {
    let cfg = train_config(p, opts, opts.workers);
    let elbo_set = opts.track_elbo.then(|| p.train.head(4096));
    let res = train(
        &cfg,
        p.theta0.data.clone(),
        p.train.shard(opts.workers),
        factory,
        Some(native_eval_factory(p.layout, p.test.clone(), elbo_set)),
    );
    BaselineResult { theta: res.theta, trace: res.trace, wall_secs: res.wall_secs }
}

/// ADVGP over an on-disk [`ShardSet`] (ISSUE 3): each worker streams
/// minibatch chunks from its shard file instead of holding a resident
/// clone — peak per-worker data is one chunk buffer.  Worker count is
/// the store's *logical* worker count (ISSUE 7): after an `advgp store
/// repartition` a worker's group may span several chunk-restricted
/// readers, pooled round-robin.
pub fn run_advgp_store(
    p: &Problem,
    opts: &MethodOpts,
    store: &ShardSet,
    factory: EngineFactory,
) -> Result<BaselineResult> {
    use crate::ps::worker::StorePool;
    use std::sync::{Arc, Mutex};
    let cfg = train_config(p, opts, store.logical_workers());
    let sources: Vec<WorkerSource> = store
        .reader_groups()?
        .into_iter()
        .enumerate()
        .map(|(w, mut group)| {
            if group.len() == 1 {
                WorkerSource::Store(group.pop().unwrap())
            } else {
                // The coordinator re-homes this placeholder inbox onto
                // the run's shared one (`pool_source`).
                WorkerSource::Pool(StorePool::from_readers(
                    w,
                    group,
                    Arc::new(Mutex::new(Vec::new())),
                ))
            }
        })
        .collect();
    let elbo_set = opts.track_elbo.then(|| p.train.head(4096));
    let res = train_sources(
        &cfg,
        p.theta0.data.clone(),
        sources,
        factory,
        Some(native_eval_factory(p.layout, p.test.clone(), elbo_set)),
    );
    Ok(BaselineResult { theta: res.theta, trace: res.trace, wall_secs: res.wall_secs })
}

/// ADVGP with the pure-Rust engine (scaling benches, baseline parity).
pub fn run_advgp(p: &Problem, opts: &MethodOpts) -> BaselineResult {
    run_advgp_with(p, opts, native_factory(p.layout))
}

/// DistGP-GD (synchronous map-reduce gradient descent).
pub fn run_distgp_gd_method(p: &Problem, opts: &MethodOpts) -> BaselineResult {
    let cfg = DistGpConfig {
        iters: u64::MAX / 2,
        eval_every: 5,
        time_limit_secs: Some(opts.budget_secs),
        ..Default::default()
    };
    let shards = p.train.shard(opts.workers);
    run_distgp_gd(&cfg, p.theta0.clone(), &shards, &p.test, native_factory(p.layout))
}

/// DistGP-LBFGS (synchronous map-reduce L-BFGS).
pub fn run_distgp_lbfgs_method(p: &Problem, opts: &MethodOpts) -> BaselineResult {
    let cfg = DistGpConfig {
        iters: u64::MAX / 2,
        eval_every: 2,
        time_limit_secs: Some(opts.budget_secs),
        ..Default::default()
    };
    let shards = p.train.shard(opts.workers);
    run_distgp_lbfgs(&cfg, p.theta0.clone(), &shards, &p.test, native_factory(p.layout))
}

/// SVIGP (single-machine stochastic variational inference).
pub fn run_svigp_method(p: &Problem, opts: &MethodOpts) -> BaselineResult {
    let cfg = SvigpConfig {
        steps: u64::MAX / 2,
        batch: 1000.min(p.train.n()),
        time_limit_secs: Some(opts.budget_secs),
        eval_every: 10,
        ..Default::default()
    };
    run_svigp(&cfg, p.theta0.clone(), &p.train, &p.test)
}

/// VW-style linear regression.
pub fn run_linear_method(p: &Problem, opts: &MethodOpts) -> BaselineResult {
    let cfg = LinearConfig {
        epochs: 1000,
        time_limit_secs: Some(opts.budget_secs),
        eval_every_rows: (p.train.n() / 4).max(1),
        ..Default::default()
    };
    run_linear(&cfg, &p.train, &p.test).1
}

/// Mean predictor (instant).
pub fn run_mean_method(p: &Problem) -> BaselineResult {
    let mp = MeanPredictor::fit(&p.train);
    let rmse = mp.rmse_on(&p.test);
    BaselineResult {
        theta: vec![mp.mean],
        trace: vec![TraceRow { t_secs: 0.0, version: 0, rmse, mnlp: f64::NAN, neg_elbo: None }],
        wall_secs: 0.0,
    }
}

/// Final (minimum observed) RMSE of a trace — methods are evaluated at
/// their best point within the budget, like the paper's "at convergence".
pub fn final_rmse(r: &BaselineResult) -> f64 {
    r.trace
        .iter()
        .map(|t| t.rmse)
        .fold(f64::INFINITY, f64::min)
}

pub fn final_mnlp(r: &BaselineResult) -> f64 {
    r.trace
        .iter()
        .map(|t| t.mnlp)
        .filter(|v| v.is_finite())
        .fold(f64::INFINITY, f64::min)
}

pub fn final_neg_elbo(r: &BaselineResult) -> Option<f64> {
    r.trace
        .iter()
        .filter_map(|t| t.neg_elbo)
        .fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.min(v))))
}
