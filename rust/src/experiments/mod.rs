//! Shared experiment harness used by `cargo bench` targets, examples,
//! and the CLI: dataset setup, method runners, table/trace output.
//!
//! Every bench honours `ADVGP_BENCH_SCALE` ∈ {ci, small, paper}
//! (default `small`) so the whole suite runs in minutes on a laptop but
//! can be scaled to the paper's sizes on a big box.

pub mod harness;
pub mod methods;

use crate::data::{kmeans, synth, Dataset, Standardizer};
use crate::gp::{Theta, ThetaLayout};
use crate::util::rng::Pcg64;
use std::path::PathBuf;

/// Experiment scale knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Ci,
    Small,
    Paper,
}

impl Scale {
    pub fn from_env() -> Self {
        match std::env::var("ADVGP_BENCH_SCALE").as_deref() {
            Ok("ci") => Scale::Ci,
            Ok("paper") => Scale::Paper,
            _ => Scale::Small,
        }
    }

    /// Scale a (ci, small, paper) triple.
    pub fn pick<T>(&self, ci: T, small: T, paper: T) -> T {
        match self {
            Scale::Ci => ci,
            Scale::Small => small,
            Scale::Paper => paper,
        }
    }
}

/// Where benches drop CSV traces and tables.
pub fn out_dir() -> PathBuf {
    let p = PathBuf::from("target/bench_out");
    let _ = std::fs::create_dir_all(&p);
    p
}

/// A standardized train/test problem with k-means-initialized θ.
pub struct Problem {
    pub train: Dataset,
    pub test: Dataset,
    pub layout: ThetaLayout,
    pub theta0: Theta,
    pub standardizer: Standardizer,
}

pub fn make_problem(
    raw: Dataset,
    n_test: usize,
    m: usize,
    kmeans_subset: usize,
    seed: u64,
) -> Problem {
    let mut ds = raw;
    let mut rng = Pcg64::new(seed, 31);
    ds.shuffle(&mut rng);
    let (mut train, mut test) = ds.split(n_test);
    let st = Standardizer::fit(&train);
    st.apply(&mut train);
    st.apply(&mut test);
    let layout = ThetaLayout::new(m, train.d());
    // Paper §6.3: inducing points from k-means centers of a subsample.
    let sub = train.head(kmeans_subset.min(train.n()));
    let z = kmeans::kmeans(&sub.x, m, 20, &mut rng);
    let theta0 = Theta::init(layout, &z);
    Problem { train, test, layout, theta0, standardizer: st }
}

/// Flight-like problem (Tables 1–2, Figs 1–3, Appendix C/D).
pub fn flight_problem(n_train: usize, n_test: usize, m: usize, seed: u64) -> Problem {
    let raw = synth::flight_like(n_train + n_test, seed);
    make_problem(raw, n_test, m, 20_000, seed)
}

/// Taxi-like problem (Fig. 4).
pub fn taxi_problem(n_train: usize, n_test: usize, m: usize, seed: u64) -> Problem {
    let raw = synth::taxi_like(n_train + n_test, seed);
    make_problem(raw, n_test, m, 50_000, seed)
}

/// Render a markdown-ish table to stdout (and return it for files).
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = format!("\n## {title}\n\n");
    out.push_str(&format!("| {} |\n", header.join(" | ")));
    out.push_str(&format!("|{}\n", "---|".repeat(header.len())));
    for row in rows {
        out.push_str(&format!("| {} |\n", row.join(" | ")));
    }
    print!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_env_parsing() {
        assert_eq!(Scale::Small.pick(1, 2, 3), 2);
        assert_eq!(Scale::Ci.pick(1, 2, 3), 1);
        assert_eq!(Scale::Paper.pick(1, 2, 3), 3);
    }

    #[test]
    fn problem_is_standardized_and_initialized() {
        let p = flight_problem(2000, 300, 10, 1);
        assert_eq!(p.train.n(), 2000);
        assert_eq!(p.test.n(), 300);
        assert_eq!(p.layout.m, 10);
        assert_eq!(p.layout.d, 8);
        // Train targets standardized.
        let mean: f64 = p.train.y.iter().sum::<f64>() / 2000.0;
        assert!(mean.abs() < 1e-8);
        // θ init follows the paper: μ=0, U=I.
        assert!(p.theta0.mu().iter().all(|&v| v == 0.0));
    }
}
