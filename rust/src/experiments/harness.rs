//! Micro-benchmark harness (offline build: no `criterion`).
//!
//! Warms up, runs timed iterations, reports mean/std/min and a rough
//! ops/sec figure.  Used by `cargo bench` targets (harness = false).

use crate::util::{Stats, Stopwatch};

pub struct BenchReport {
    pub name: String,
    pub iters: u64,
    pub stats: Stats,
}

impl BenchReport {
    pub fn print(&self) {
        let mean = self.stats.mean();
        println!(
            "{:<44} {:>12}  ±{:>10}  min {:>10}  ({:.1}/s, n={})",
            self.name,
            fmt_secs(mean),
            fmt_secs(self.stats.std()),
            fmt_secs(self.stats.min),
            1.0 / mean.max(1e-12),
            self.iters
        );
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Time `f` for at least `min_secs` (after `warmup` runs).
pub fn bench<F: FnMut()>(name: &str, warmup: u64, min_secs: f64, mut f: F) -> BenchReport {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    let total = Stopwatch::start();
    let mut iters = 0u64;
    while total.secs() < min_secs || iters < 5 {
        let sw = Stopwatch::start();
        f();
        stats.push(sw.secs());
        iters += 1;
        if iters > 100_000 {
            break;
        }
    }
    let r = BenchReport { name: name.to_string(), iters, stats };
    r.print();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_stats() {
        let mut x = 0u64;
        let r = bench("noop-ish", 2, 0.01, || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(r.iters >= 5);
        assert!(r.stats.mean() >= 0.0);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_secs(2e-9).ends_with("ns"));
        assert!(fmt_secs(2e-5).ends_with("µs"));
        assert!(fmt_secs(2e-2).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
    }
}
