//! # ADVGP — Asynchronous Distributed Variational Gaussian Processes
//!
//! A faithful, production-shaped reproduction of *"Asynchronous
//! Distributed Variational Gaussian Process for Regression"* (Peng, Zhe,
//! Zhang, Qi; 2017): a weight-space-augmented variational GP whose
//! negative ELBO decomposes as `Σ_k G_k(θ) + h(θ)`, optimized by
//! bounded-staleness (delay-limit τ) proximal gradient descent on a
//! parameter-server topology.
//!
//! Architecture (see DESIGN.md and docs/ARCHITECTURE.md):
//! * **L3 (this crate)** — the coordinator: parameter server, workers,
//!   delay gate, proximal updates, out-of-core shard store +
//!   checkpoint/restore ([`data::store`], [`ps::checkpoint`]),
//!   baselines, metrics, benches.
//! * **L2 (python/compile/model.py)** — the JAX objective/gradients,
//!   AOT-lowered to HLO text in `artifacts/`.
//! * **L1 (python/compile/kernels/ard_phi.py)** — the fused Pallas
//!   feature-map kernel inside every artifact.
//!
//! Python never runs at inference/training time; the Rust binary loads
//! the artifacts through PJRT (`runtime`) or falls back to a pure-Rust
//! gradient engine (`grad::native`) that implements the same math.

pub mod baselines;
pub mod data;
pub mod experiments;
pub mod gp;
pub mod grad;
pub mod kernel;
pub mod linalg;
pub mod opt;
pub mod ps;
pub mod runtime;
pub mod serve;
pub mod testing;
pub mod util;
