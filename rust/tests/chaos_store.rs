//! Chaos, storage edition (ISSUE 7): deterministic disk-fault injection
//! ([`StoreFaultPlan`], ADVGPFI1 extended from sockets to disk) against
//! full in-process training runs streaming from checksummed ADVGPSH2
//! shard stores.
//!
//! The acceptance criteria pinned here:
//!
//! * a seeded corruption matrix over {flipped byte, scribbled chunk} is
//!   detected at read time — every corrupt chunk is quarantined (counted
//!   in [`ServerStats::store_quarantines`], in exact agreement with an
//!   offline `verify_store` scrub) and the run still converges in
//!   degraded mode under the corruption budget;
//! * corruption denser than the budget fails **typed**
//!   ([`StoreFault::BudgetDry`]) and ends the run promptly — never a
//!   hang, never a poisoned gradient;
//! * the same seed replays the same fault plan, the same applied-fault
//!   trace, and the same per-reader quarantine trace;
//! * a logically repartitioned store (W → W′ without rewriting bytes)
//!   trains across its chunk-restricted reader groups.
//!
//! [`ServerStats::store_quarantines`]: advgp::ps::metrics::ServerStats
//! [`StoreFault::BudgetDry`]: advgp::data::store::StoreFault

use advgp::data::store::{verify_store, QuarantinePolicy, ShardSet, StoreFault};
use advgp::data::{kmeans, synth, Dataset, Standardizer};
use advgp::gp::{Theta, ThetaLayout};
use advgp::grad::native_factory;
use advgp::linalg::Mat;
use advgp::ps::coordinator::{train_sources, TrainConfig};
use advgp::ps::worker::{StorePool, WorkerProfile, WorkerSource};
use advgp::ps::{StoreFaultEvent, StoreFaultPlan, StoreFaultRule};
use advgp::util::rng::Pcg64;
use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Standardized friedman problem + kmeans-initialized θ (the idiom
/// shared with `rust/tests/chaos_ps.rs`).
fn setup(n: usize, m: usize, seed: u64) -> (Dataset, Dataset, Theta, ThetaLayout) {
    let mut ds = synth::friedman(n + 200, 4, 0.4, seed);
    let mut rng = Pcg64::seeded(seed);
    ds.shuffle(&mut rng);
    let (mut train_ds, mut test_ds) = ds.split(200);
    let st = Standardizer::fit(&train_ds);
    st.apply(&mut train_ds);
    st.apply(&mut test_ds);
    let layout = ThetaLayout::new(m, 4);
    let z = kmeans::kmeans(&train_ds.x, m, 15, &mut rng);
    let theta = Theta::init(layout, &z);
    (train_ds, test_ds, theta, layout)
}

/// Fresh ADVGPSH2 store under the test temp root.
fn store_at(name: &str, ds: &Dataset, r: usize, chunk_rows: usize) -> ShardSet {
    let dir = std::env::temp_dir().join("advgp_chaos_store").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    ShardSet::create(&dir, ds, r, chunk_rows).unwrap()
}

fn one_thread() -> WorkerProfile {
    WorkerProfile { threads: 1, ..Default::default() }
}

fn chaos_cfg(layout: ThetaLayout, max_updates: u64, workers: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 2;
    cfg.max_updates = max_updates;
    cfg.eval_every_secs = 0.0;
    cfg.profiles = vec![one_thread(); workers];
    // The no-hang backstop: a run that livelocks under corruption is
    // shut down typed by the watchdog, and the test still finishes.
    cfg.time_limit_secs = Some(30.0);
    cfg
}

fn assert_finite(theta: &[f64], what: &str) {
    for (i, v) in theta.iter().enumerate() {
        assert!(v.is_finite(), "{what}: θ[{i}] = {v} is not finite");
    }
}

fn empty_win() -> Dataset {
    Dataset { x: Mat::empty(), y: Vec::new() }
}

/// The chunk-level (quarantinable) event alphabet: no `TruncateAt`,
/// which beheads a whole file at open time and is pinned separately in
/// `ps/fault.rs`.  Each event appears exactly once per seeded plan, so
/// no two rules can XOR-cancel each other.
fn chunk_events() -> [StoreFaultEvent; 3] {
    [
        StoreFaultEvent::CorruptByte(3),
        StoreFaultEvent::ScribbleChunk,
        StoreFaultEvent::CorruptByte(17),
    ]
}

/// The store's reader groups lowered to worker sources, exactly as
/// `run_advgp_store` does it (multi-reader groups pool round-robin; the
/// coordinator re-homes the placeholder inbox).
fn sources_of(set: &ShardSet) -> Vec<WorkerSource> {
    set.reader_groups()
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(w, mut group)| {
            if group.len() == 1 {
                WorkerSource::Store(group.pop().unwrap())
            } else {
                WorkerSource::Pool(StorePool::from_readers(
                    w,
                    group,
                    Arc::new(Mutex::new(Vec::new())),
                ))
            }
        })
        .collect()
}

/// The tentpole matrix: seeded chunk corruption against a live training
/// run.  Every corrupt chunk must be caught at read time and
/// quarantined — the run converges in degraded mode, and the server's
/// quarantine count agrees *exactly* with an offline scrub of the same
/// store (nothing double-counted, nothing missed, nothing corrupt ever
/// reaching the gradient path).
#[test]
fn seeded_corruption_matrix_trains_degraded_within_the_budget() {
    let (train_ds, _test, theta, layout) = setup(400, 6, 61);
    let max_updates = 12;
    // CI pins these seeds (.github/workflows/ci.yml): a failure here is
    // replayable from the seed alone.
    for (i, seed) in [0x57AB_0001u64, 0x57AB_0002].into_iter().enumerate() {
        // 2 files × 200 rows, chunks of 25 → 8 chunks per file.
        let set = store_at(&format!("matrix_{i}"), &train_ds, 2, 25);
        let events = chunk_events();
        let plan = StoreFaultPlan::seeded(seed, &events, 2, 8);
        assert_eq!(
            plan,
            StoreFaultPlan::seeded(seed, &events, 2, 8),
            "same seed must draw the same plan"
        );
        let trace = plan.apply(set.dir()).unwrap();
        assert!(!trace.is_empty(), "seed {seed:#x}: nothing applied");
        // Ground truth from the offline scrub: the distinct chunks the
        // plan actually corrupted.
        let report = verify_store(set.dir()).unwrap();
        let corrupt = report.total_corrupt();
        assert!(corrupt >= 1, "seed {seed:#x}: scrub found the store clean");
        assert!(!report.clean());

        let cfg = chaos_cfg(layout, max_updates, 2);
        let run = train_sources(
            &cfg,
            theta.data.clone(),
            sources_of(&set),
            native_factory(layout),
            None,
        );
        assert_eq!(
            run.stats.updates, max_updates,
            "seed {seed:#x}: degraded-mode run must still converge \
             ({} corrupt chunk(s) ≤ budget)",
            corrupt
        );
        assert_finite(&run.theta, &format!("seed {seed:#x} degraded"));
        // Each reader owns its file for the whole run and quarantines a
        // chunk exactly once, so the session count equals the scrub's.
        assert_eq!(
            run.stats.store_quarantines, corrupt as u64,
            "seed {seed:#x}: quarantine count must match the offline scrub"
        );
    }
}

/// Corruption denser than the budget: every chunk of both files
/// scribbled.  At the reader level the failure is typed
/// ([`StoreFault::BudgetDry`]); at the run level both workers depart
/// and the run ends promptly with zero updates — corrupt data never
/// reaches the gradient path, and nothing hangs until the watchdog.
#[test]
fn corruption_beyond_the_budget_fails_typed_and_ends_the_run() {
    let (train_ds, _test, theta, layout) = setup(400, 6, 63);
    // 2 files × 200 rows, chunks of 16 → 13 chunks per file, all
    // corrupted: the default budget of 8 runs dry with no verified
    // read ever refilling it.
    let set = store_at("budget_dry", &train_ds, 2, 16);
    let rules: Vec<StoreFaultRule> = (0..2)
        .flat_map(|f| {
            (0..13).map(move |c| StoreFaultRule {
                file: f,
                chunk: c,
                event: StoreFaultEvent::ScribbleChunk,
            })
        })
        .collect();
    let applied = StoreFaultPlan::new(rules.clone()).apply(set.dir()).unwrap();
    assert_eq!(applied.len(), rules.len());

    // Reader level: the failure is the typed budget error, not a panic
    // and not silently empty data.
    let mut r = set.reader(0).unwrap();
    r.set_fault_policy(QuarantinePolicy::new_default());
    let err = r.next_window(&mut empty_win()).unwrap_err();
    match err.downcast_ref::<StoreFault>() {
        Some(StoreFault::BudgetDry { max, .. }) => assert_eq!(*max, 8),
        other => panic!("expected BudgetDry, got {other:?} ({err:#})"),
    }

    // Run level: both workers hit the dry budget on their first window,
    // leave, and the run ends long before the 30 s watchdog with no
    // update ever aggregated from poisoned bytes.
    let cfg = chaos_cfg(layout, 12, 2);
    let run = train_sources(
        &cfg,
        theta.data.clone(),
        sources_of(&set),
        native_factory(layout),
        None,
    );
    assert_eq!(run.stats.updates, 0, "no update may form from a poisoned store");
    assert_eq!(run.stats.pushes, 0);
    assert!(
        run.wall_secs < 29.0,
        "the run must end typed, not be shot by the watchdog ({:.1}s)",
        run.wall_secs
    );
    assert!(run.stats.leaves >= 1, "departing workers must be observed");
    assert!(
        run.stats.store_quarantines >= 8,
        "every budget token spent is a counted quarantine (got {})",
        run.stats.store_quarantines
    );
}

/// Reproducibility, end to end: the same seed draws the same plan,
/// applies the same fault trace to identical stores, and a degraded
/// reader pass over each store quarantines the same chunks in the same
/// order — every chaos failure is replayable from its seed alone.
#[test]
fn same_seed_replays_the_same_quarantine_trace() {
    let ds = synth::friedman(240, 3, 0.3, 9);
    let run_once = |name: &str| -> (Vec<StoreFaultRule>, Vec<Vec<usize>>) {
        // 2 files × 120 rows, chunks of 15 → 8 chunks per file.
        let set = store_at(name, &ds, 2, 15);
        let plan = StoreFaultPlan::seeded(0xABAD_D15C, &chunk_events(), 2, 8);
        let applied = plan.apply(set.dir()).unwrap();
        let quarantines = (0..set.r())
            .map(|k| {
                let mut r = set.reader(k).unwrap();
                r.set_fault_policy(QuarantinePolicy::new_default());
                // One full-shard window walks every chunk, quarantining
                // all corrupt ones in encounter order.
                r.set_chunk_rows(r.n());
                r.next_window(&mut empty_win()).unwrap();
                r.quarantine_trace()
            })
            .collect();
        (applied, quarantines)
    };
    let (trace_a, quar_a) = run_once("replay_a");
    let (trace_b, quar_b) = run_once("replay_b");
    assert!(!trace_a.is_empty());
    assert_eq!(trace_a, trace_b, "same seed must apply the same fault trace");
    assert_eq!(quar_a, quar_b, "same seed must replay the same quarantine trace");
    assert!(
        quar_a.iter().map(Vec::len).sum::<usize>() >= 1,
        "the degraded pass must have quarantined something"
    );
    // The quarantined chunks are exactly the applied plan's targets.
    let planned: BTreeSet<(usize, usize)> =
        trace_a.iter().map(|r| (r.file, r.chunk)).collect();
    let seen: BTreeSet<(usize, usize)> = quar_a
        .iter()
        .enumerate()
        .flat_map(|(f, cs)| cs.iter().map(move |&c| (f, c)))
        .collect();
    assert_eq!(seen, planned);
}

/// Logical repartitioning (W → W′ without rewriting shard bytes): a
/// 2-file store remapped to 3 workers hands out chunk-restricted reader
/// groups that cover every row exactly once, and a full training run
/// over those groups converges.
#[test]
fn repartitioned_store_trains_across_chunk_restricted_reader_groups() {
    let (train_ds, _test, theta, layout) = setup(400, 6, 67);
    // 2 files × 200 rows, chunks of 25 → 16 chunks total.
    let mut set = store_at("repartition", &train_ds, 2, 25);
    let dir: PathBuf = set.dir().to_path_buf();
    let shard_bytes = |dir: &PathBuf| -> Vec<Vec<u8>> {
        (0..2)
            .map(|k| std::fs::read(dir.join(format!("shard_{k:03}.bin"))).unwrap())
            .collect()
    };
    let before = shard_bytes(&dir);
    set.repartition(3).unwrap();
    assert_eq!(
        shard_bytes(&dir),
        before,
        "repartitioning must not rewrite shard bytes"
    );
    // The remap survives the manifest roundtrip.
    let set = ShardSet::open(set.dir()).unwrap();
    assert_eq!((set.r(), set.logical_workers()), (2, 3));
    let groups = set.reader_groups().unwrap();
    assert_eq!(groups.len(), 3);
    assert!(
        groups.iter().any(|g| g.len() > 1),
        "16 chunks over 3 workers must give some worker a two-file group"
    );
    let rows: usize = groups.iter().flatten().map(|r| r.n()).sum();
    assert_eq!(rows, 400, "the groups must cover every row exactly once");

    let max_updates = 10;
    let cfg = chaos_cfg(layout, max_updates, 3);
    let run = train_sources(
        &cfg,
        theta.data.clone(),
        sources_of(&set),
        native_factory(layout),
        None,
    );
    assert_eq!(
        run.stats.updates, max_updates,
        "training over the repartitioned groups must converge"
    );
    assert_finite(&run.theta, "repartitioned");
    assert_eq!(run.stats.store_quarantines, 0, "the store is intact");
}
