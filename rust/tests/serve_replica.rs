//! Integration: the read-path replica fleet (ADVGPSV1, ISSUE 8).
//!
//! The acceptance criteria pinned here:
//! * a replica subscribed to a τ=0 loopback training run (S ∈ {1, 2}
//!   slice servers) converges to the trainer's final θ version with a
//!   posterior **bitwise-equal** to an in-process [`PosteriorCache`]
//!   installed from the run's returned θ — and its over-the-wire
//!   PREDICT answers are bitwise-equal to in-process predictions;
//! * after the trainer's clean SHUTDOWN the replica keeps serving the
//!   final posterior (a finished model is final, not stale);
//! * admission control is typed and per-request: a bad-dimension
//!   PREDICT draws `REJECT(REJ_BAD_DIM)` and the session survives it;
//! * the `serve_fleet` smoke: two replicas behind the open-loop load
//!   generator answer every request with zero rejects and consistent θ
//!   versions (the CI step of the same name runs this test).

use advgp::data::{kmeans, synth, Dataset, Standardizer};
use advgp::gp::{Theta, ThetaLayout};
use advgp::grad::native_factory;
use advgp::ps::coordinator::{train_remote, train_remote_sharded, TrainConfig};
use advgp::ps::net::{remote_worker_loop, sharded_worker_loop, NetServer};
use advgp::ps::worker::{WorkerProfile, WorkerSource};
use advgp::ps::RunResult;
use advgp::serve::{
    loadgen, LoadgenConfig, PosteriorCache, PredictAnswer, PredictClient, Replica,
    ReplicaConfig,
};
use advgp::util::rng::Pcg64;
use std::time::Duration;

const UPDATES: u64 = 20;

/// Standardized friedman problem + kmeans-initialized θ (the same
/// setup the sharded-PS suite trains on).
fn setup(n: usize, m: usize, seed: u64) -> (Dataset, Theta, ThetaLayout) {
    let mut ds = synth::friedman(n, 4, 0.4, seed);
    let mut rng = Pcg64::seeded(seed);
    ds.shuffle(&mut rng);
    let st = Standardizer::fit(&ds);
    st.apply(&mut ds);
    let layout = ThetaLayout::new(m, 4);
    let z = kmeans::kmeans(&ds.x, m, 15, &mut rng);
    let theta = Theta::init(layout, &z);
    (ds, theta, layout)
}

fn one_thread() -> WorkerProfile {
    WorkerProfile { threads: 1, ..Default::default() }
}

fn assert_bitwise(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: [{i}] diverged ({x} vs {y})");
    }
}

/// Run a τ=0 loopback training run over `servers` slice servers with
/// `replicas` subscribed replicas, and return (train result, replicas).
/// Order matters: the trainer's accept loops must be live before the
/// replicas subscribe, and the replicas must subscribe before the
/// workers exist (training cannot end without them, so no subscription
/// can miss the run).
fn train_with_replicas(
    ds: &Dataset,
    theta0: &Theta,
    layout: ThetaLayout,
    servers: usize,
    replicas: usize,
) -> (RunResult, Vec<Replica>) {
    let nets: Vec<NetServer> =
        (0..servers).map(|_| NetServer::bind("127.0.0.1:0").unwrap()).collect();
    let addrs: Vec<String> = nets.iter().map(|n| n.local_addr().to_string()).collect();
    let trainer = {
        let theta0 = theta0.data.clone();
        std::thread::spawn(move || {
            let mut cfg = TrainConfig::new(layout);
            cfg.tau = 0;
            cfg.max_updates = UPDATES;
            cfg.eval_every_secs = 0.0;
            if nets.len() > 1 {
                train_remote_sharded(&cfg, theta0, nets, 2, None)
            } else {
                train_remote(&cfg, theta0, nets.into_iter().next().unwrap(), 2, None)
            }
        })
    };
    let fleet: Vec<Replica> = (0..replicas)
        .map(|_| Replica::start("127.0.0.1:0", &addrs, ReplicaConfig::default()).unwrap())
        .collect();
    let workers: Vec<_> = ds
        .shard(2)
        .into_iter()
        .enumerate()
        .map(|(k, shard)| {
            let addrs = addrs.clone();
            std::thread::spawn(move || {
                if addrs.len() > 1 {
                    sharded_worker_loop(
                        &addrs,
                        Some(k),
                        WorkerSource::Memory(shard),
                        native_factory(layout),
                        one_thread(),
                    )
                    .unwrap()
                } else {
                    remote_worker_loop(
                        &addrs[0],
                        Some(k),
                        WorkerSource::Memory(shard),
                        native_factory(layout),
                        one_thread(),
                    )
                    .unwrap()
                }
            })
        })
        .collect();
    let run = trainer.join().unwrap();
    for w in workers {
        w.join().unwrap();
    }
    (run, fleet)
}

/// Deterministic predict inputs.
fn predict_rows(n: usize, d: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::seeded(seed);
    (0..n * d).map(|_| rng.next_f64() * 2.0 - 1.0).collect()
}

/// The tentpole acceptance test: for S ∈ {1, 2} slice servers, a
/// subscribed replica's posterior at the final θ version is bitwise
/// identical to an in-process cache installed from the run's returned
/// θ — and the answers it serves over the wire are bitwise identical
/// to in-process predictions from that cache.
#[test]
fn replica_posterior_matches_in_process_cache_bitwise() {
    let (ds, theta0, layout) = setup(400, 6, 41);
    for servers in [1usize, 2] {
        let (run, mut fleet) = train_with_replicas(&ds, &theta0, layout, servers, 1);
        assert_eq!(run.stats.updates, UPDATES, "S={servers}: run length");
        let replica = fleet.pop().unwrap();
        assert!(
            replica.wait_version(UPDATES, Duration::from_secs(30)),
            "S={servers}: replica stuck at θ v{:?}",
            replica.version()
        );
        // The trainer ended cleanly — the replica serves the final θ.
        assert!(replica.wait_trainer_end(Duration::from_secs(30)));
        assert_eq!(replica.version(), Some(UPDATES), "S={servers}: final version");

        // In-process reference cache at the same version.
        let cache = PosteriorCache::new(layout);
        assert!(cache.install(UPDATES, &run.theta));
        let reference = cache.get().unwrap();
        let served = replica.cache().get().unwrap();
        assert_eq!(served.version, UPDATES);
        assert_bitwise(
            &reference.gp.theta.data,
            &served.gp.theta.data,
            &format!("S={servers}: replica θ vs in-process θ"),
        );

        // Over-the-wire answers vs in-process predictions: bitwise.
        let rows = predict_rows(16, layout.d, 99);
        let xb = advgp::linalg::Mat::from_vec(16, layout.d, rows.clone());
        let mut ws = advgp::gp::PredictWorkspace::new();
        let (mut mean, mut var) = (Vec::new(), Vec::new());
        reference.gp.predict_into(&xb, &mut ws, &mut mean, &mut var);
        let mut client = PredictClient::connect(&replica.predict_addr().to_string()).unwrap();
        assert_eq!((client.m, client.d), (layout.m, layout.d), "handshake layout");
        assert_eq!(client.version, UPDATES, "handshake version");
        match client.predict(&rows).unwrap() {
            PredictAnswer::Prediction { version, mean: wm, var: wv } => {
                assert_eq!(version, UPDATES, "S={servers}: answer version");
                assert_bitwise(&mean, &wm, &format!("S={servers}: wire mean"));
                assert_bitwise(&var, &wv, &format!("S={servers}: wire var"));
            }
            PredictAnswer::Rejected { code, message } => {
                panic!("S={servers}: healthy replica rejected ({code}: {message})")
            }
        }
        let report = replica.shutdown();
        assert!(report.rows >= 16, "S={servers}: rows answered");
    }
}

/// Admission control is per-request and typed: a PREDICT whose rows
/// have the wrong feature dimension draws `REJECT(REJ_BAD_DIM)` and
/// the session keeps working afterwards.
#[test]
fn bad_dimension_predict_is_rejected_without_killing_the_session() {
    use advgp::ps::wire::{self, Frame, REJ_BAD_DIM};
    let (ds, theta0, layout) = setup(300, 5, 43);
    let (run, mut fleet) = train_with_replicas(&ds, &theta0, layout, 1, 1);
    let replica = fleet.pop().unwrap();
    assert!(replica.wait_version(run.stats.updates, Duration::from_secs(30)));

    let mut client = PredictClient::connect(&replica.predict_addr().to_string()).unwrap();
    // A raw PREDICT with d+1 columns (PredictClient's own send()
    // guards the dimension, so craft the frame directly).
    let wrong_d = (layout.d + 1) as u64;
    let mut stream =
        std::net::TcpStream::connect(replica.predict_addr()).expect("second session");
    wire::write_frame(
        &mut stream,
        &Frame::Subscribe {
            proto: wire::PROTO_VERSION,
            scope: wire::SUBSCRIBE_PREDICT,
        },
    )
    .unwrap();
    let mut scratch = Vec::new();
    let ack = wire::read_frame(&mut stream, &mut scratch).unwrap();
    assert!(matches!(ack, Frame::PosteriorSync { ref theta, .. } if theta.is_empty()));
    wire::write_frame(
        &mut stream,
        &Frame::Predict { id: 7, d: wrong_d, rows: vec![0.0; wrong_d as usize] },
    )
    .unwrap();
    match wire::read_frame(&mut stream, &mut scratch).unwrap() {
        Frame::Reject { id, code, .. } => {
            assert_eq!((id, code), (7, REJ_BAD_DIM), "typed per-request verdict");
        }
        f => panic!("expected REJECT, got kind {:#04x}", f.kind()),
    }
    // The same session answers a well-formed PREDICT afterwards.
    wire::write_frame(
        &mut stream,
        &Frame::Predict { id: 8, d: layout.d as u64, rows: vec![0.1; layout.d] },
    )
    .unwrap();
    match wire::read_frame(&mut stream, &mut scratch).unwrap() {
        Frame::Prediction { id, mean, .. } => {
            assert_eq!(id, 8);
            assert_eq!(mean.len(), 1);
        }
        f => panic!("expected PREDICTION, got kind {:#04x}", f.kind()),
    }
    // And the first client's session was never disturbed.
    match client.predict(&predict_rows(2, layout.d, 5)).unwrap() {
        PredictAnswer::Prediction { mean, .. } => assert_eq!(mean.len(), 2),
        PredictAnswer::Rejected { code, message } => {
            panic!("healthy request rejected ({code}: {message})")
        }
    }
    assert_eq!(replica.rejects().bad_dim.load(std::sync::atomic::Ordering::Relaxed), 1);
    replica.shutdown();
}

/// The `serve_fleet` smoke (mirrored by the CI step of the same name):
/// two replicas subscribed to one training fleet, open-loop load across
/// both — every request answered, zero rejects, every answer at the
/// final θ version.
#[test]
fn serve_fleet_two_replicas_answer_offered_load() {
    let (ds, theta0, layout) = setup(300, 5, 47);
    let (run, fleet) = train_with_replicas(&ds, &theta0, layout, 1, 2);
    for (i, r) in fleet.iter().enumerate() {
        assert!(
            r.wait_version(run.stats.updates, Duration::from_secs(30)),
            "replica {i} stuck at θ v{:?}",
            r.version()
        );
    }
    let addrs: Vec<String> = fleet.iter().map(|r| r.predict_addr().to_string()).collect();
    let cfg = LoadgenConfig {
        qps: 300.0,
        requests: 150,
        rows_per_request: 4,
        seed: 9,
    };
    let sb = loadgen::run(&addrs, &cfg).unwrap();
    assert_eq!(sb.answered, cfg.requests, "every request answered");
    assert_eq!(sb.rows, cfg.requests * cfg.rows_per_request);
    assert_eq!(sb.total_rejects(), 0, "healthy fleet rejected traffic");
    assert_eq!(sb.broken_sessions, 0);
    assert_eq!(
        (sb.min_version, sb.max_version),
        (run.stats.updates, run.stats.updates),
        "all answers at the final θ version"
    );
    assert!(sb.rows_per_sec > 0.0);
    assert_eq!(sb.latencies_ns.len(), cfg.requests);
    for r in fleet {
        let report = r.shutdown();
        assert_eq!(report.first_version, run.stats.updates);
    }
}
