//! Integration: the partitioned parameter server (ISSUE 5) — θ sharded
//! over S independent server loops, in-process and over loopback TCP.
//!
//! The acceptance criteria pinned here:
//! * τ=0 sharded runs (S ∈ {1, 2, 3} in-process; S = 2 loopback TCP)
//!   reproduce the single-server θ trajectory **bitwise**;
//! * a sharded checkpoint (per-slice ADVGPCK1 files + topology
//!   manifest) resumes bitwise — including *across* topologies (a
//!   single server can resume a sharded directory);
//! * a worker killed mid-run is retired from **every** slice gate so
//!   the survivors finish;
//! * an ADVGPNT1 (rev-1) peer still interoperates with an unsharded
//!   rev-2 server, and is cleanly rejected by a slice server it cannot
//!   address;
//! * a wedged-but-connected worker is retired by the PING/PONG
//!   heartbeat;
//! * `remote_worker_loop` reconnects with bounded backoff.

use advgp::data::{kmeans, synth, Dataset, Standardizer};
use advgp::gp::{Theta, ThetaLayout};
use advgp::grad::native_factory;
use advgp::ps::coordinator::{train, train_remote, train_remote_sharded, TrainConfig};
use advgp::ps::net::{
    remote_worker_loop_with, sharded_worker_loop, NetServer, ReconnectPolicy,
};
use advgp::ps::wire::{self, Frame, ERR_PROTO, PROTO_NT1, PROTO_NT2};
use advgp::ps::worker::{WorkerProfile, WorkerSource};
use advgp::ps::{checkpoint, Checkpoint};
use advgp::util::rng::Pcg64;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;

fn tdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("advgp_sharded_test").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Standardized friedman problem + kmeans-initialized θ.
fn setup(n: usize, m: usize, seed: u64) -> (Dataset, Dataset, Theta, ThetaLayout) {
    let mut ds = synth::friedman(n + 200, 4, 0.4, seed);
    let mut rng = Pcg64::seeded(seed);
    ds.shuffle(&mut rng);
    let (mut train_ds, mut test_ds) = ds.split(200);
    let st = Standardizer::fit(&train_ds);
    st.apply(&mut train_ds);
    st.apply(&mut test_ds);
    let layout = ThetaLayout::new(m, 4);
    let z = kmeans::kmeans(&train_ds.x, m, 15, &mut rng);
    let theta = Theta::init(layout, &z);
    (train_ds, test_ds, theta, layout)
}

/// Fixed per-worker thread budgets: the gradient engine's lane
/// reduction is deterministic *per budget*, so bitwise comparisons pin
/// every worker to one lane on both topologies.
fn one_thread() -> WorkerProfile {
    WorkerProfile { threads: 1, ..Default::default() }
}

fn assert_bitwise(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: θ[{i}] diverged ({x} vs {y})");
    }
}

/// The tentpole acceptance test, in-process: at τ=0, partitioning θ
/// over S ∈ {2, 3} slice servers reproduces the single-server (S=1)
/// trajectory bitwise — element-wise separability taken to the
/// process level.
#[test]
fn tau0_sharded_in_process_matches_single_server_bitwise() {
    let (train_ds, _test, theta, layout) = setup(400, 6, 31);
    let shards = train_ds.shard(2);
    let run = |servers: usize| {
        let mut cfg = TrainConfig::new(layout);
        cfg.tau = 0;
        cfg.max_updates = 25;
        cfg.eval_every_secs = 0.0;
        cfg.servers = servers;
        cfg.profiles = vec![one_thread(), one_thread()];
        train(&cfg, theta.data.clone(), shards.clone(), native_factory(layout), None)
    };
    let single = run(1);
    assert_eq!(single.stats.updates, 25);
    for s in [2, 3] {
        let sharded = run(s);
        assert_eq!(sharded.stats.updates, 25, "S={s}: version-vector floor");
        assert_bitwise(&single.theta, &sharded.theta, &format!("S={s} vs single"));
        // Each worker push fans out once per slice.
        assert_eq!(sharded.stats.pushes, single.stats.pushes * s as u64, "S={s} pushes");
    }
}

/// The loopback-TCP twin: 2 slice servers, 2 sharded workers connecting
/// to both (`ADVGPNT2` WELCOME2/PUBLISH2/PUSH2), τ=0 — bitwise equal to
/// the in-process single-server run.
#[test]
fn tau0_sharded_loopback_tcp_matches_single_server_bitwise() {
    let (train_ds, _test, theta, layout) = setup(400, 6, 33);
    let shards = train_ds.shard(2);
    let mk_cfg = || {
        let mut cfg = TrainConfig::new(layout);
        cfg.tau = 0;
        cfg.max_updates = 20;
        cfg.eval_every_secs = 0.0;
        cfg.profiles = vec![one_thread(), one_thread()];
        cfg
    };
    // In-process single-server reference.
    let local = train(
        &mk_cfg(),
        theta.data.clone(),
        shards.clone(),
        native_factory(layout),
        None,
    );
    assert_eq!(local.stats.updates, 20);

    // Two slice servers on loopback; each worker connects to both.
    let nets: Vec<NetServer> =
        (0..2).map(|_| NetServer::bind("127.0.0.1:0").unwrap()).collect();
    let addrs: Vec<String> = nets.iter().map(|n| n.local_addr().to_string()).collect();
    let workers: Vec<_> = shards
        .into_iter()
        .enumerate()
        .map(|(k, shard)| {
            let addrs = addrs.clone();
            std::thread::spawn(move || {
                sharded_worker_loop(
                    &addrs,
                    Some(k),
                    WorkerSource::Memory(shard),
                    native_factory(layout),
                    one_thread(),
                )
                .unwrap()
            })
        })
        .collect();
    let remote = train_remote_sharded(&mk_cfg(), theta.data.clone(), nets, 2, None);
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(remote.stats.updates, 20);
    assert_bitwise(&local.theta, &remote.theta, "loopback S=2 vs in-process single");
}

/// Sharded durability: per-slice ADVGPCK1 files under `slice_*/`, a
/// topology manifest at the root, per-slice keep-last GC — and a resume
/// that lands bitwise on the uninterrupted single-server trajectory,
/// from BOTH a sharded continuation (S=2) and a single-server
/// continuation of the same sharded directory (cross-topology resume).
#[test]
fn sharded_checkpoint_resumes_bitwise_across_topologies() {
    let ckdir = tdir("resume");
    let (train_ds, _test, theta, layout) = setup(300, 6, 35);
    let shards = train_ds.shard(2);
    let run = |servers: usize, max: u64, every: u64, resume: Option<Checkpoint>| {
        let mut cfg = TrainConfig::new(layout);
        cfg.tau = 0;
        cfg.max_updates = max;
        cfg.eval_every_secs = 0.0;
        cfg.servers = servers;
        cfg.profiles = vec![one_thread(), one_thread()];
        cfg.checkpoint_every = every;
        cfg.checkpoint_dir = (every > 0).then(|| ckdir.clone());
        cfg.keep_last = (every > 0).then_some(2);
        cfg.resume_from = resume;
        train(&cfg, theta.data.clone(), shards.clone(), native_factory(layout), None)
    };

    // Leg 1: sharded (S=2), 15 updates, checkpoint every 5, keep 2.
    let leg1 = run(2, 15, 5, None);
    assert_eq!(leg1.stats.updates, 15);
    assert!(ckdir.join("topology.json").is_file(), "topology manifest at the root");
    for i in 0..2 {
        let sdir = Checkpoint::slice_dir(&ckdir, i, 2);
        let files = Checkpoint::list_in(&sdir).unwrap();
        assert!(
            !files.is_empty() && files.len() <= 2,
            "slice {i}: keep_last=2 retained {} files",
            files.len()
        );
    }
    // The assembled checkpoint is the single-server checkpoint, bitwise.
    let ck = Checkpoint::load_latest_any(&ckdir).unwrap().expect("sealed");
    assert_eq!(ck.version, 15);
    assert_eq!(ck.theta.len(), layout.len());
    assert_bitwise(&ck.theta, &leg1.theta, "assembled seal vs leg-1 θ");

    // Uninterrupted single-server reference to 30.
    let direct = run(1, 30, 0, None);

    // Sharded resume → 30: bitwise.
    let resumed_sharded = run(2, 30, 0, Some(ck.clone()));
    assert_eq!(resumed_sharded.stats.updates, 30);
    assert_bitwise(&direct.theta, &resumed_sharded.theta, "sharded resume");

    // Cross-topology: a SINGLE server resuming the sharded directory's
    // assembled state — same trajectory, bitwise.
    let resumed_single = run(1, 30, 0, Some(ck));
    assert_eq!(resumed_single.stats.updates, 30);
    assert_bitwise(&direct.theta, &resumed_single.theta, "cross-topology resume");
}

/// Kill-one-worker gate behavior on a partitioned fleet: a worker that
/// handshakes with both slice servers, pushes one fragment to each, and
/// vanishes without EXIT must have its clock retired on EVERY slice —
/// at τ=2 a single lingering clock would stall the run within three
/// updates.
#[test]
fn killed_worker_is_retired_on_every_slice() {
    let (train_ds, _test, theta, layout) = setup(600, 8, 37);
    let shards = train_ds.shard(2);
    let nets: Vec<NetServer> =
        (0..2).map(|_| NetServer::bind("127.0.0.1:0").unwrap()).collect();
    let addrs: Vec<String> = nets.iter().map(|n| n.local_addr().to_string()).collect();

    // Two well-behaved sharded workers own the real shards.
    let workers: Vec<_> = shards
        .into_iter()
        .enumerate()
        .map(|(k, shard)| {
            let addrs = addrs.clone();
            std::thread::spawn(move || {
                sharded_worker_loop(
                    &addrs,
                    Some(k),
                    WorkerSource::Memory(shard),
                    native_factory(layout),
                    one_thread(),
                )
                .unwrap()
            })
        })
        .collect();

    // The flaky third member: raw ADVGPNT2 client against both slice
    // servers — HELLO, read WELCOME2 + initial PUBLISH2, push one
    // all-zero fragment, then drop both sockets (kill -9, not EXIT).
    let flaky = {
        let addrs = addrs.clone();
        std::thread::spawn(move || {
            let mut socks = Vec::new();
            for addr in &addrs {
                let mut s = TcpStream::connect(addr).unwrap();
                wire::write_frame(&mut s, &Frame::Hello { proto: PROTO_NT2, worker: 2 })
                    .unwrap();
                let mut scratch = Vec::new();
                let (slice_id, start, len) =
                    match wire::read_frame(&mut s, &mut scratch).unwrap() {
                        Frame::Welcome2 { worker, slice_id, start, end, .. } => {
                            assert_eq!(worker, 2);
                            (slice_id, start, (end - start) as usize)
                        }
                        f => panic!("expected WELCOME2, got {f:?}"),
                    };
                let version = match wire::read_frame(&mut s, &mut scratch).unwrap() {
                    Frame::Publish2 { version, theta, .. } => {
                        assert_eq!(theta.len(), len);
                        version
                    }
                    f => panic!("expected PUBLISH2, got {f:?}"),
                };
                let push = advgp::ps::messages::Push {
                    worker: 2,
                    version,
                    value: 0.0,
                    grad: vec![0.0; len],
                    compute_secs: 0.0,
                };
                wire::write_frame(&mut s, &Frame::Push2 { slice_id, start, push }).unwrap();
                socks.push(s);
            }
            drop(socks); // vanish from the whole fleet at once
        })
    };

    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 2;
    cfg.max_updates = 40;
    cfg.eval_every_secs = 0.0;
    cfg.time_limit_secs = Some(60.0); // hang backstop only; never hit
    let res = train_remote_sharded(&cfg, theta.data.clone(), nets, 3, None);
    flaky.join().unwrap();
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(
        res.stats.updates, 40,
        "survivors must finish the run after the fleet-wide disconnect"
    );
    assert!(res.stats.leaves >= 1, "the EOF must be observed as a departure");
    assert!(res.stats.staleness.max <= cfg.tau as f64);
}

/// Version negotiation at the fleet boundary: a rev-1 peer keeps
/// working against an *unsharded* rev-2 server (that interop is pinned
/// by `net_transport.rs`), but a slice server cannot be addressed by
/// rev-1 frames at all — the handshake must say so explicitly.
#[test]
fn rev1_client_is_rejected_by_a_slice_server_only() {
    let (_train, _test, theta, layout) = setup(200, 4, 39);
    let nets: Vec<NetServer> =
        (0..2).map(|_| NetServer::bind("127.0.0.1:0").unwrap()).collect();
    let addr0 = nets[0].local_addr().to_string();
    let server = {
        let mut cfg = TrainConfig::new(layout);
        cfg.tau = 0;
        cfg.max_updates = 5;
        cfg.eval_every_secs = 0.0;
        cfg.time_limit_secs = Some(2.0); // nobody real ever joins
        let theta0 = theta.data.clone();
        std::thread::spawn(move || train_remote_sharded(&cfg, theta0, nets, 1, None))
    };
    // Rev-1 HELLO at a slice server → ERR_PROTO with a pointer to rev 2.
    let mut s = TcpStream::connect(&addr0).unwrap();
    wire::write_frame(&mut s, &Frame::Hello { proto: PROTO_NT1, worker: 0 }).unwrap();
    let mut scratch = Vec::new();
    match wire::read_frame(&mut s, &mut scratch).unwrap() {
        Frame::Error { code, message } => {
            assert_eq!(code, ERR_PROTO);
            assert!(message.contains("slice"), "error should explain the slice: {message}");
        }
        f => panic!("expected ERROR, got {f:?}"),
    }
    drop(s);
    let res = server.join().unwrap();
    assert_eq!(res.stats.updates, 0);
}

/// WAN hardening: a worker that handshakes, pushes once, then wedges —
/// socket open, nothing ever read or written again — is retired by the
/// PING + grace heartbeat, and the survivors finish the run.  Without
/// the heartbeat this exact topology deadlocks until the wall-clock
/// watchdog (the pre-ISSUE-5 documented gap).
#[test]
fn wedged_worker_is_retired_by_heartbeat() {
    let (train_ds, _test, theta, layout) = setup(400, 6, 41);
    let shards = train_ds.shard(2);
    let net = NetServer::bind("127.0.0.1:0").unwrap();
    let addr = net.local_addr().to_string();

    // Worker 0: healthy, owns shard 0 (remote_worker_loop answers PONGs
    // through its publish pump).
    let healthy = {
        let addr = addr.clone();
        let shard = shards[0].clone();
        std::thread::spawn(move || {
            remote_worker_loop_with(
                &addr,
                Some(0),
                WorkerSource::Memory(shard),
                native_factory(layout),
                one_thread(),
                ReconnectPolicy::default(),
            )
            .unwrap()
        })
    };
    // Worker 1: handshakes (rev 2), pushes one real-shaped gradient,
    // then sleeps forever without reading — wedged, not disconnected.
    let dim = layout.len();
    let _wedged = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut s = TcpStream::connect(&addr).unwrap();
            wire::write_frame(&mut s, &Frame::Hello { proto: PROTO_NT2, worker: 1 })
                .unwrap();
            let mut scratch = Vec::new();
            match wire::read_frame(&mut s, &mut scratch).unwrap() {
                Frame::Welcome2 { worker: 1, .. } => {}
                f => panic!("expected WELCOME2, got {f:?}"),
            }
            let version = match wire::read_frame(&mut s, &mut scratch).unwrap() {
                Frame::Publish2 { version, .. } => version,
                f => panic!("expected PUBLISH2, got {f:?}"),
            };
            let push = advgp::ps::messages::Push {
                worker: 1,
                version,
                value: 0.0,
                grad: vec![0.0; dim],
                compute_secs: 0.0,
            };
            wire::write_frame(&mut s, &Frame::Push2 { slice_id: 0, start: 0, push })
                .unwrap();
            // Wedge: hold the socket, answer nothing.  (Not joined; the
            // thread parks well past the test's lifetime.)
            std::thread::sleep(std::time::Duration::from_secs(30));
            drop(s);
        })
    };

    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 2;
    cfg.max_updates = 30;
    cfg.eval_every_secs = 0.0;
    cfg.heartbeat_secs = 0.2; // PING after 200 ms silence, retire after 400 ms
    cfg.time_limit_secs = Some(60.0); // backstop only — the heartbeat must win
    let start = std::time::Instant::now();
    let res = train_remote(&cfg, theta.data.clone(), net, 2, None);
    healthy.join().unwrap();
    assert_eq!(res.stats.updates, 30, "survivor must finish after the wedge retires");
    assert!(res.stats.leaves >= 1, "the wedged worker must count as a departure");
    assert!(
        start.elapsed() < std::time::Duration::from_secs(30),
        "the heartbeat, not the watchdog, must have retired the wedge"
    );
}

/// WAN hardening: the reconnect loop retries the initial connect with
/// bounded backoff, so a worker started before its server still joins.
#[test]
fn worker_retries_connect_until_the_server_binds() {
    let (train_ds, _test, theta, layout) = setup(200, 4, 43);
    // Reserve a port, free it, and bind the real server there shortly
    // after the worker has already started dialing.
    let port = {
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        probe.local_addr().unwrap().port()
    };
    let addr = format!("127.0.0.1:{port}");
    let worker = {
        let addr = addr.clone();
        let shard = train_ds.clone();
        std::thread::spawn(move || {
            remote_worker_loop_with(
                &addr,
                Some(0),
                WorkerSource::Memory(shard),
                native_factory(layout),
                one_thread(),
                ReconnectPolicy {
                    max_retries: 60,
                    base: std::time::Duration::from_millis(50),
                    cap: std::time::Duration::from_millis(200),
                },
            )
            .unwrap()
        })
    };
    std::thread::sleep(std::time::Duration::from_millis(400));
    let net = NetServer::bind(&addr).unwrap();
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 0;
    cfg.max_updates = 5;
    cfg.eval_every_secs = 0.0;
    cfg.time_limit_secs = Some(30.0);
    let res = train_remote(&cfg, theta.data.clone(), net, 1, None);
    assert_eq!(res.stats.updates, 5, "the late-dialing worker must have joined");
    assert_eq!(worker.join().unwrap(), 0);
}

/// The lineage manifest travels with sharded checkpoint directories
/// too: each run (fresh, then resumed) appends one record at the root.
#[test]
fn sharded_lineage_records_fresh_and_resumed_runs() {
    let ckdir = tdir("lineage");
    let (train_ds, _test, theta, layout) = setup(200, 4, 45);
    let shards = train_ds.shard(2);
    let run = |max: u64, resume: Option<Checkpoint>| {
        let mut cfg = TrainConfig::new(layout);
        cfg.tau = 0;
        cfg.max_updates = max;
        cfg.eval_every_secs = 0.0;
        cfg.servers = 2;
        cfg.profiles = vec![one_thread(), one_thread()];
        cfg.checkpoint_every = 5;
        cfg.checkpoint_dir = Some(ckdir.clone());
        cfg.resume_from = resume;
        train(&cfg, theta.data.clone(), shards.clone(), native_factory(layout), None)
    };
    run(10, None);
    let ck = Checkpoint::load_latest_any(&ckdir).unwrap().expect("sealed");
    run(20, Some(ck));
    let records = checkpoint::read_lineage(&ckdir).unwrap();
    assert_eq!(records.len(), 2, "one record per completed run");
    assert_eq!(records[0].resumed_from, None);
    assert_eq!(records[0].step, 10);
    assert_eq!(records[1].resumed_from, Some(10));
    assert_eq!(records[1].step, 20);
    assert_ne!(records[0].run_id, records[1].run_id);
    let prov = checkpoint::provenance(&ckdir).unwrap();
    assert!(prov.contains(&records[0].run_id) && prov.contains("resumed from v10"));
}
