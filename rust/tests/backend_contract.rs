//! The per-backend tolerance contracts (ISSUE 10, ADVGPBE1).
//!
//! Generalizes PR 1's bitwise-equivalence suite into one contract per
//! [`ComputeBackend`]:
//!
//! * **Scalar** — bitwise-pinned: every trait method reproduces the
//!   PR-1 `Mat`/`kernel` call it replaced, bit for bit, so the default
//!   backend cannot drift from seed behavior.  A τ=0 training run with
//!   `TrainConfig::backend = Scalar` reproduces the default-config θ
//!   trajectory bitwise.
//! * **SIMD** — split by kernel family.  The broadcast-chain kernels
//!   (matmul, trᵀ·matmul, gram, column ops, triangular row products)
//!   are recompiled copies of the scalar kernels with independent
//!   accumulator chains and must stay **bitwise** equal.  The reduction
//!   kernels (dot, sumsq, matvec, prefix/suffix-dot triangular
//!   transposes, the kernel cross rows) reassociate the horizontal sum
//!   into 8 lanes; their contract is element-wise *relative* error
//!   bounded by [`REL_TOL`] against the scalar result, checked over
//!   adversarial shapes (empty, 1 element, just below/above lane
//!   multiples).  Dispatch-path consistency (AVX2 vs generic) is
//!   bitwise and pinned by `simd::self_check` — CI runs this file a
//!   second time under `ADVGP_SIMD_FALLBACK=1` to cover the forced
//!   generic path on SIMD-capable hosts.
//!
//! Selection plumbing is contract-tested too: unknown names are typed
//! errors (never panics), `auto` resolves by host capability, and the
//! posterior/gradient stacks produce within-tolerance results under an
//! explicitly pinned SIMD backend.

use advgp::data::{kmeans, synth, Standardizer};
use advgp::gp::{SparseGp, Theta, ThetaLayout};
use advgp::grad::{native::NativeEngine, GradEngine};
use advgp::kernel::{self, ArdParams, CrossScratch};
use advgp::linalg::{simd, Mat};
use advgp::ps::coordinator::{train, TrainConfig};
use advgp::ps::worker::WorkerProfile;
use advgp::runtime::{Backend, ComputeBackend};
use advgp::util::rng::Pcg64;

/// The SIMD reduction-kernel contract: element-wise relative error vs
/// the scalar reference.  8-lane reassociation of a k-term sum perturbs
/// each partial by O(k·ε) in the worst case; for the k ≤ a few thousand
/// of these tests (and the well-conditioned values the model produces)
/// 1e-12 is a comfortable, documented bound.
const REL_TOL: f64 = 1e-12;

fn scalar() -> &'static dyn ComputeBackend {
    Backend::Scalar.resolve().expect("scalar resolves")
}

fn simd_be() -> &'static dyn ComputeBackend {
    Backend::Simd.resolve().expect("simd resolves")
}

fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> Mat {
    Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
}

fn rand_vec(rng: &mut Pcg64, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

/// Lower-triangular with a well-conditioned diagonal.
fn rand_tril(rng: &mut Pcg64, n: usize) -> Mat {
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..i {
            l[(i, j)] = rng.normal() * 0.3;
        }
        l[(i, i)] = 0.7 + rng.next_f64();
    }
    l
}

fn rand_triu(rng: &mut Pcg64, n: usize) -> Mat {
    rand_tril(rng, n).transpose()
}

fn assert_bitwise_mat(a: &Mat, b: &Mat, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i} ({x} vs {y})");
    }
}

fn assert_bitwise_vec(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i} ({x} vs {y})");
    }
}

fn assert_close_vec(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = x.abs().max(y.abs()).max(1.0);
        assert!(
            (x - y).abs() <= tol * scale,
            "{what}: elem {i} out of tolerance ({x} vs {y}, rel {:.2e})",
            (x - y).abs() / scale
        );
    }
}

fn assert_close_mat(a: &Mat, b: &Mat, tol: f64, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
    assert_close_vec(&a.data, &b.data, tol, what);
}

/// Adversarial shape set: empty, single row/col, just below / at /
/// above the 8-lane width, and a larger non-multiple.
const DIMS: [usize; 7] = [0, 1, 3, 7, 8, 9, 21];

// ---------------------------------------------------------------------
// Scalar contract: the trait delegates bitwise.
// ---------------------------------------------------------------------

/// Every `ScalarBackend` method must reproduce the `Mat`/`kernel` call
/// it replaced, bitwise, on random shapes — the trait seam added by
/// ISSUE 10 is not allowed to perturb seed behavior.
#[test]
fn scalar_backend_is_bitwise_the_mat_kernels() {
    let be = scalar();
    assert_eq!(be.name(), "scalar");
    let mut rng = Pcg64::seeded(0xBE01);
    for (r, k, c) in [(5usize, 4usize, 6usize), (1, 7, 3), (16, 9, 8)] {
        let a = rand_mat(&mut rng, r, k);
        let b = rand_mat(&mut rng, k, c);
        let mut got = Mat::empty();
        be.matmul_into(&a, &b, &mut got);
        assert_bitwise_mat(&got, &a.matmul(&b), "matmul");

        let b2 = rand_mat(&mut rng, r, c);
        be.tr_matmul_into(&a, &b2, &mut got);
        let mut want = Mat::empty();
        a.tr_matmul_into(&b2, &mut want);
        assert_bitwise_mat(&got, &want, "tr_matmul");

        be.gram_into(&a, &mut got);
        a.gram_into(&mut want);
        assert_bitwise_mat(&got, &want, "gram");

        let x = rand_vec(&mut rng, k);
        let mut gv = Vec::new();
        let mut wv = Vec::new();
        be.matvec_into(&a, &x, &mut gv);
        a.matvec_into(&x, &mut wv);
        assert_bitwise_vec(&gv, &wv, "matvec");

        let xr = rand_vec(&mut rng, r);
        be.tr_matvec_into(&a, &xr, &mut gv);
        a.tr_matvec_into(&xr, &mut wv);
        assert_bitwise_vec(&gv, &wv, "tr_matvec");

        be.col_sums_into(&a, &mut gv);
        a.col_sums_into(&mut wv);
        assert_bitwise_vec(&gv, &wv, "col_sums");

        let l = rand_tril(&mut rng, k);
        let u = rand_triu(&mut rng, k);
        be.mul_tril_into(&a, &l, &mut got);
        a.mul_tril_into(&l, &mut want);
        assert_bitwise_mat(&got, &want, "mul_tril");
        be.mul_triu_into(&a, &u, &mut got);
        a.mul_triu_into(&u, &mut want);
        assert_bitwise_mat(&got, &want, "mul_triu");
        be.mul_tril_t_into(&a, &l, &mut got);
        a.mul_tril_t_into(&l, &mut want);
        assert_bitwise_mat(&got, &want, "mul_tril_t");
        be.mul_triu_t_into(&a, &u, &mut got);
        a.mul_triu_t_into(&u, &mut want);
        assert_bitwise_mat(&got, &want, "mul_triu_t");

        let bk = rand_mat(&mut rng, k, c);
        be.triu_matmul_into(&u, &bk, &mut got);
        u.triu_matmul_into(&bk, &mut want);
        assert_bitwise_mat(&got, &want, "triu_matmul");

        let v = rand_vec(&mut rng, k);
        let w = rand_vec(&mut rng, k);
        assert_eq!(
            be.dot(&v, &w).to_bits(),
            advgp::linalg::dot(&v, &w).to_bits(),
            "dot"
        );
        // sumsq must be dot(v, v) — the predict path's historic form.
        assert_eq!(
            be.sumsq(&v).to_bits(),
            advgp::linalg::dot(&v, &v).to_bits(),
            "sumsq"
        );
    }
    // The kernel surface.
    let p = ArdParams { log_a0: 0.15, log_eta: vec![0.1, -0.3, 0.2] };
    let x = rand_mat(&mut rng, 11, 3);
    let z = rand_mat(&mut rng, 6, 3);
    let mut got = Mat::empty();
    let mut ws = CrossScratch::new();
    be.cross_into_ws(&p, &x, &z, &mut got, &mut ws);
    assert_bitwise_mat(&got, &kernel::cross(&p, &x, &z), "cross_into_ws");
    assert_bitwise_mat(
        &be.cross_pairwise(&p, &x, &z),
        &kernel::cross_pairwise(&p, &x, &z),
        "cross_pairwise",
    );
}

// ---------------------------------------------------------------------
// SIMD contract, broadcast-chain family: bitwise.
// ---------------------------------------------------------------------

/// The SIMD broadcast-chain kernels keep scalar's accumulation order
/// (independent per-output chains, no reassociation, no FMA) — their
/// contract is bitwise equality with the scalar backend on every
/// shape, including non-lane-multiples and empties.
#[test]
fn simd_broadcast_chain_kernels_are_bitwise_scalar() {
    let sc = scalar();
    let sv = simd_be();
    assert_eq!(sv.name(), "simd");
    let mut rng = Pcg64::seeded(0xBE02);
    for &k in &DIMS {
        let (r, c) = (9usize, 5usize);
        let a = rand_mat(&mut rng, r, k);
        let (mut got, mut want) = (Mat::empty(), Mat::empty());
        if k > 0 {
            let b = rand_mat(&mut rng, k, c);
            sv.matmul_into(&a, &b, &mut got);
            sc.matmul_into(&a, &b, &mut want);
            assert_bitwise_mat(&got, &want, &format!("matmul k={k}"));

            let l = rand_tril(&mut rng, k);
            let u = rand_triu(&mut rng, k);
            sv.mul_tril_into(&a, &l, &mut got);
            sc.mul_tril_into(&a, &l, &mut want);
            assert_bitwise_mat(&got, &want, &format!("mul_tril k={k}"));
            sv.mul_triu_into(&a, &u, &mut got);
            sc.mul_triu_into(&a, &u, &mut want);
            assert_bitwise_mat(&got, &want, &format!("mul_triu k={k}"));

            let bk = rand_mat(&mut rng, k, c);
            sv.triu_matmul_into(&u, &bk, &mut got);
            sc.triu_matmul_into(&u, &bk, &mut want);
            assert_bitwise_mat(&got, &want, &format!("triu_matmul k={k}"));
        }
        let a2 = rand_mat(&mut rng, k, c);
        let b2 = rand_mat(&mut rng, k, 4);
        sv.tr_matmul_into(&a2, &b2, &mut got);
        sc.tr_matmul_into(&a2, &b2, &mut want);
        assert_bitwise_mat(&got, &want, &format!("tr_matmul rows={k}"));

        sv.gram_into(&a2, &mut got);
        sc.gram_into(&a2, &mut want);
        assert_bitwise_mat(&got, &want, &format!("gram rows={k}"));

        let x = rand_vec(&mut rng, k);
        let (mut gv, mut wv) = (Vec::new(), Vec::new());
        sv.tr_matvec_into(&a2, &x, &mut gv);
        sc.tr_matvec_into(&a2, &x, &mut wv);
        assert_bitwise_vec(&gv, &wv, &format!("tr_matvec rows={k}"));

        sv.col_sums_into(&a2, &mut gv);
        sc.col_sums_into(&a2, &mut wv);
        assert_bitwise_vec(&gv, &wv, &format!("col_sums rows={k}"));
    }
}

// ---------------------------------------------------------------------
// SIMD contract, reduction family: bounded relative error.
// ---------------------------------------------------------------------

/// The SIMD reduction kernels reassociate into 8 lanes — their
/// contract is element-wise relative error ≤ [`REL_TOL`] vs scalar,
/// over adversarial lengths (0, 1, lane-1, lane, lane+1, …).
#[test]
fn simd_reduction_kernels_within_tolerance_of_scalar() {
    let sc = scalar();
    let sv = simd_be();
    let mut rng = Pcg64::seeded(0xBE03);
    for &n in &DIMS {
        let a = rand_vec(&mut rng, n);
        let b = rand_vec(&mut rng, n);
        assert_close_vec(&[sv.dot(&a, &b)], &[sc.dot(&a, &b)], REL_TOL, &format!("dot n={n}"));
        assert_close_vec(&[sv.sumsq(&a)], &[sc.sumsq(&a)], REL_TOL, &format!("sumsq n={n}"));

        let m = rand_mat(&mut rng, 5, n);
        let (mut gv, mut wv) = (Vec::new(), Vec::new());
        sv.matvec_into(&m, &a, &mut gv);
        sc.matvec_into(&m, &a, &mut wv);
        assert_close_vec(&gv, &wv, REL_TOL, &format!("matvec cols={n}"));

        if n > 0 {
            let rows = rand_mat(&mut rng, 6, n);
            let l = rand_tril(&mut rng, n);
            let u = rand_triu(&mut rng, n);
            let (mut got, mut want) = (Mat::empty(), Mat::empty());
            sv.mul_tril_t_into(&rows, &l, &mut got);
            sc.mul_tril_t_into(&rows, &l, &mut want);
            assert_close_mat(&got, &want, REL_TOL, &format!("mul_tril_t n={n}"));
            sv.mul_triu_t_into(&rows, &u, &mut got);
            sc.mul_triu_t_into(&rows, &u, &mut want);
            assert_close_mat(&got, &want, REL_TOL, &format!("mul_triu_t n={n}"));
        }
    }
    // Kernel cross rows: empty/1-row x and z, non-lane-multiple d.
    for &(rows, m, d) in &[(0usize, 4usize, 3usize), (1, 1, 9), (13, 7, 5), (33, 8, 8)] {
        let p = ArdParams { log_a0: 0.1, log_eta: vec![-0.1; d] };
        let x = rand_mat(&mut rng, rows, d);
        let z = rand_mat(&mut rng, m, d);
        let (mut got, mut want) = (Mat::empty(), Mat::empty());
        let (mut ws_a, mut ws_b) = (CrossScratch::new(), CrossScratch::new());
        sv.cross_into_ws(&p, &x, &z, &mut got, &mut ws_a);
        sc.cross_into_ws(&p, &x, &z, &mut want, &mut ws_b);
        assert_close_mat(&got, &want, REL_TOL, &format!("cross {rows}x{m} d={d}"));
        assert_close_mat(
            &sv.cross_pairwise(&p, &x, &z),
            &sc.cross_pairwise(&p, &x, &z),
            REL_TOL,
            &format!("cross_pairwise {rows}x{m} d={d}"),
        );
    }
}

/// Dispatched (AVX2 or arch-specific) vs generic copies of every SIMD
/// kernel must agree **bitwise** — the dispatch path is a performance
/// decision, never a numerics decision.  Run a second time under
/// `ADVGP_SIMD_FALLBACK=1` in CI to pin the forced-generic path.
#[test]
fn simd_dispatch_paths_agree_bitwise() {
    simd::self_check().unwrap_or_else(|e| panic!("simd self-check failed: {e}"));
    // Introspection coherent with the dispatch decision.
    let path = simd::active_path();
    assert!(
        ["x86_64-avx2", "generic", "aarch64-neon"].contains(&path),
        "unexpected simd path {path:?}"
    );
}

// ---------------------------------------------------------------------
// Selection plumbing.
// ---------------------------------------------------------------------

/// `ADVGP_BACKEND` / `--backend` parsing: unknown values are typed
/// errors (never a panic), the env path falls back to scalar, and
/// `auto` resolves by host capability.
#[test]
fn backend_selection_contract() {
    // Typed error, names the bad value and the valid set.
    let err = Backend::parse("gpu").unwrap_err();
    assert!(err.0.contains("gpu") && err.0.contains("scalar|simd|auto|xla"), "{err}");
    // Env semantics (tested through the value-injected core — no
    // process-global env mutation in a threaded test binary).
    assert_eq!(Backend::from_env_value(None), Backend::Scalar);
    assert_eq!(Backend::from_env_value(Some("  ")), Backend::Scalar);
    assert_eq!(Backend::from_env_value(Some("SIMD")), Backend::Simd);
    assert_eq!(Backend::from_env_value(Some("bogus")), Backend::Scalar);
    // Auto resolves to simd exactly when the host has a vector path;
    // note `available()` ignores ADVGP_SIMD_FALLBACK by design (the
    // fallback pins the *dispatch* path inside the SIMD backend, it
    // does not demote backend selection).
    let auto = Backend::Auto.resolve().unwrap();
    let expect = if simd::available() { "simd" } else { "scalar" };
    assert_eq!(auto.name(), expect);
    #[cfg(not(feature = "xla"))]
    {
        let err = Backend::Xla.resolve().unwrap_err();
        assert!(err.0.contains("features xla"), "{err}");
    }
}

// ---------------------------------------------------------------------
// Stack-level contracts.
// ---------------------------------------------------------------------

fn posterior_setup(seed: u64) -> (Theta, Mat, Vec<f64>) {
    let mut ds = synth::friedman(500, 4, 0.4, seed);
    let mut rng = Pcg64::seeded(seed);
    ds.shuffle(&mut rng);
    let st = Standardizer::fit(&ds);
    st.apply(&mut ds);
    let layout = ThetaLayout::new(12, 4);
    let z = kmeans::kmeans(&ds.x, 12, 15, &mut rng);
    let mut theta = Theta::init(layout, &z);
    for v in theta.mu_mut() {
        *v = rng.normal() * 0.3;
    }
    (theta, ds.x, ds.y)
}

/// The blocked posterior under a pinned SIMD backend stays within the
/// reduction tolerance of the scalar posterior (means are produced by
/// reduction kernels here, so the contract is `REL_TOL`-close, not
/// bitwise).
#[test]
fn sparse_gp_simd_predict_within_tolerance_of_scalar() {
    let (theta, x, y) = posterior_setup(71);
    let gp_s = SparseGp::with_backend(theta.clone(), scalar());
    let gp_v = SparseGp::with_backend(theta, simd_be());
    let (ms, vs) = gp_s.predict(&x);
    let (mv, vv) = gp_v.predict(&x);
    // ktilde + lengthscale exponentials keep everything O(1)-scaled;
    // give the composed pipeline an order of magnitude of headroom
    // over the single-kernel bound.
    assert_close_vec(&mv, &ms, 1e-11, "predict mean");
    assert_close_vec(&vv, &vs, 1e-11, "predict var");
    let gs = gp_s.data_term(&x, &y);
    let gv = gp_v.data_term(&x, &y);
    assert!(
        (gs - gv).abs() <= 1e-10 * gs.abs().max(1.0),
        "data term: {gs} vs {gv}"
    );
}

/// The gradient engine under a pinned SIMD backend: value and every
/// gradient coordinate within composed tolerance of the scalar engine.
#[test]
fn native_grad_simd_within_tolerance_of_scalar() {
    let (theta, x, y) = posterior_setup(73);
    let layout = theta.layout;
    let mut eng_s = NativeEngine::with_backend(layout, scalar());
    let mut eng_v = NativeEngine::with_backend(layout, simd_be());
    let rs = eng_s.grad(&theta.data, &x, &y);
    let rv = eng_v.grad(&theta.data, &x, &y);
    assert!(
        (rs.value - rv.value).abs() <= 1e-10 * rs.value.abs().max(1.0),
        "value: {} vs {}",
        rs.value,
        rv.value
    );
    for i in 0..layout.len() {
        let scale = rs.grad[i].abs().max(rv.grad[i].abs()).max(1.0);
        assert!(
            (rs.grad[i] - rv.grad[i]).abs() <= 1e-9 * scale,
            "grad[{i}]: {} vs {}",
            rs.grad[i],
            rv.grad[i]
        );
    }
}

/// τ=0 training with an explicit `backend: Scalar` reproduces the
/// default-config trajectory bitwise — the config knob resolves to the
/// very same kernels the seed ran (and proves threading the backend
/// through the PS stack perturbed nothing).
#[test]
fn tau0_scalar_backend_train_matches_default_bitwise() {
    let (theta, x, y) = posterior_setup(77);
    let layout = theta.layout;
    let ds = advgp::data::Dataset { x, y };
    let shards = ds.shard(2);
    let one = || WorkerProfile { threads: 1, ..Default::default() };
    let run = |backend: Option<Backend>| {
        let mut cfg = TrainConfig::new(layout);
        cfg.tau = 0;
        cfg.max_updates = 20;
        cfg.eval_every_secs = 0.0;
        cfg.profiles = vec![one(), one()];
        if let Some(b) = backend {
            cfg.backend = b;
        }
        train(
            &cfg,
            theta.data.clone(),
            shards.clone(),
            advgp::grad::native_factory(layout),
            None,
        )
    };
    let default = run(None);
    let pinned = run(Some(Backend::Scalar));
    assert_eq!(default.stats.updates, 20);
    for (i, (a, b)) in default.theta.iter().zip(&pinned.theta).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "θ[{i}] diverged between default and pinned-scalar runs ({a} vs {b})"
        );
    }
}
