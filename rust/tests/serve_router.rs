//! Integration: the predict-side routing tier (ADVGPRT1, ISSUE 9).
//!
//! The serving contract pinned here: a [`Router`] in front of the
//! replica fleet is **answer-preserving** — every routed PREDICT
//! answer is bitwise identical to the direct-replica answer at the
//! same posterior version, whichever of the four paths produced it:
//!
//! * {cache **miss**, **solo**} — a fresh row set forwarded upstream
//!   by a single session (`routed_answers_match_direct_replica_answers_bitwise`);
//! * {cache **hit**, **solo**} — a repeated row set short-circuited by
//!   the per-leg [`AnswerCache`] (same test: after at most two misses
//!   both legs are warm, so later repeats hit whatever P2C draws);
//! * {cache **miss**, **cross-session batch**} — two concurrent routed
//!   sessions whose rows can only be answered by one fused replica
//!   batch (`max_rows` short-circuit, deadline parked far away), see
//!   `cross_session_requests_fuse_into_one_replica_batch`;
//! * {cache **hit**, batched ancestry} — the same rows re-sent after
//!   the fused round are answered from cache without the replica ever
//!   seeing another batch (same test: `report.batches` stays 1).
//!
//! Plus the failure-domain row: a severed replica's sessions keep
//! answering through the sibling with zero client-visible errors, the
//! probe retires the dead leg, and ROUTE-STATUS advertises the
//! retirement to new sessions.

use advgp::data::{kmeans, synth, Dataset, Standardizer};
use advgp::gp::{PredictWorkspace, Theta, ThetaLayout};
use advgp::grad::native_factory;
use advgp::linalg::Mat;
use advgp::ps::coordinator::{train_remote, train_remote_sharded, TrainConfig};
use advgp::ps::net::{remote_worker_loop, sharded_worker_loop, NetServer};
use advgp::ps::worker::{WorkerProfile, WorkerSource};
use advgp::ps::RunResult;
use advgp::serve::{
    BatchConfig, PosteriorCache, PredictAnswer, PredictClient, Replica, ReplicaConfig,
    Router, RouterConfig,
};
use advgp::util::rng::Pcg64;
use std::time::Duration;

const UPDATES: u64 = 20;

/// Standardized friedman problem + kmeans-initialized θ (the same
/// setup the replica and sharded-PS suites train on).
fn setup(n: usize, m: usize, seed: u64) -> (Dataset, Theta, ThetaLayout) {
    let mut ds = synth::friedman(n, 4, 0.4, seed);
    let mut rng = Pcg64::seeded(seed);
    ds.shuffle(&mut rng);
    let st = Standardizer::fit(&ds);
    st.apply(&mut ds);
    let layout = ThetaLayout::new(m, 4);
    let z = kmeans::kmeans(&ds.x, m, 15, &mut rng);
    let theta = Theta::init(layout, &z);
    (ds, theta, layout)
}

fn one_thread() -> WorkerProfile {
    WorkerProfile { threads: 1, ..Default::default() }
}

fn assert_bitwise(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: [{i}] diverged ({x} vs {y})");
    }
}

/// Run a τ=0 loopback training run over `servers` slice servers with
/// one subscribed replica per config in `cfgs`, and return (train
/// result, replicas).  Same ordering contract as the replica suite:
/// trainer accept loops live → replicas subscribe → workers start.
fn train_fleet(
    ds: &Dataset,
    theta0: &Theta,
    layout: ThetaLayout,
    servers: usize,
    cfgs: Vec<ReplicaConfig>,
) -> (RunResult, Vec<Replica>) {
    let nets: Vec<NetServer> =
        (0..servers).map(|_| NetServer::bind("127.0.0.1:0").unwrap()).collect();
    let addrs: Vec<String> = nets.iter().map(|n| n.local_addr().to_string()).collect();
    let trainer = {
        let theta0 = theta0.data.clone();
        std::thread::spawn(move || {
            let mut cfg = TrainConfig::new(layout);
            cfg.tau = 0;
            cfg.max_updates = UPDATES;
            cfg.eval_every_secs = 0.0;
            if nets.len() > 1 {
                train_remote_sharded(&cfg, theta0, nets, 2, None)
            } else {
                train_remote(&cfg, theta0, nets.into_iter().next().unwrap(), 2, None)
            }
        })
    };
    let fleet: Vec<Replica> = cfgs
        .into_iter()
        .map(|cfg| Replica::start("127.0.0.1:0", &addrs, cfg).unwrap())
        .collect();
    let workers: Vec<_> = ds
        .shard(2)
        .into_iter()
        .enumerate()
        .map(|(k, shard)| {
            let addrs = addrs.clone();
            std::thread::spawn(move || {
                if addrs.len() > 1 {
                    sharded_worker_loop(
                        &addrs,
                        Some(k),
                        WorkerSource::Memory(shard),
                        native_factory(layout),
                        one_thread(),
                    )
                    .unwrap()
                } else {
                    remote_worker_loop(
                        &addrs[0],
                        Some(k),
                        WorkerSource::Memory(shard),
                        native_factory(layout),
                        one_thread(),
                    )
                    .unwrap()
                }
            })
        })
        .collect();
    let run = trainer.join().unwrap();
    for w in workers {
        w.join().unwrap();
    }
    (run, fleet)
}

/// Deterministic predict inputs.
fn predict_rows(n: usize, d: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::seeded(seed);
    (0..n * d).map(|_| rng.next_f64() * 2.0 - 1.0).collect()
}

/// In-process reference predictions from the run's returned θ at the
/// final version — the ground truth every routed answer must match
/// bitwise.
fn reference_predict(layout: ThetaLayout, theta: &[f64], rows: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let cache = PosteriorCache::new(layout);
    assert!(cache.install(UPDATES, theta));
    let post = cache.get().unwrap();
    let xb = Mat::from_vec(rows.len() / layout.d, layout.d, rows.to_vec());
    let mut ws = PredictWorkspace::new();
    let (mut mean, mut var) = (Vec::new(), Vec::new());
    post.gp.predict_into(&xb, &mut ws, &mut mean, &mut var);
    (mean, var)
}

fn expect_prediction(
    client: &mut PredictClient,
    rows: &[f64],
    mean: &[f64],
    var: &[f64],
    what: &str,
) {
    match client.predict(rows).unwrap() {
        PredictAnswer::Prediction { version, mean: wm, var: wv } => {
            assert_eq!(version, UPDATES, "{what}: answer version");
            assert_bitwise(mean, &wm, &format!("{what}: mean"));
            assert_bitwise(var, &wv, &format!("{what}: var"));
        }
        PredictAnswer::Rejected { code, message } => {
            panic!("{what}: routed request rejected ({code}: {message})")
        }
    }
}

/// The headline acceptance test: for S ∈ {1, 2} slice servers, every
/// answer a [`Router`] over two replicas serves — cache hit or miss,
/// solo — is bitwise identical to the direct-replica answer and to the
/// in-process reference at the same posterior version.  Also pins the
/// routed handshake (same (m, d, version) contract as a replica) and
/// ROUTE-STATUS absorption by an unmodified [`PredictClient`].
#[test]
fn routed_answers_match_direct_replica_answers_bitwise() {
    let (ds, theta0, layout) = setup(400, 6, 41);
    for servers in [1usize, 2] {
        let (run, fleet) =
            train_fleet(&ds, &theta0, layout, servers, vec![ReplicaConfig::default(); 2]);
        assert_eq!(run.stats.updates, UPDATES, "S={servers}: run length");
        for (i, r) in fleet.iter().enumerate() {
            assert!(
                r.wait_version(UPDATES, Duration::from_secs(30)),
                "S={servers}: replica {i} stuck at θ v{:?}",
                r.version()
            );
            assert!(r.wait_trainer_end(Duration::from_secs(30)));
        }
        let addrs: Vec<String> = fleet.iter().map(|r| r.predict_addr().to_string()).collect();
        let router = Router::start("127.0.0.1:0", &addrs, RouterConfig::default()).unwrap();

        let rows = predict_rows(8, layout.d, 99);
        let (mean, var) = reference_predict(layout, &run.theta, &rows);

        // Ground the contract: every replica's *direct* answer equals
        // the in-process reference, so "routed == reference" below is
        // exactly "routed == direct" whichever leg answered.
        for (i, addr) in addrs.iter().enumerate() {
            let mut direct = PredictClient::connect(addr).unwrap();
            expect_prediction(&mut direct, &rows, &mean, &var, &format!("S={servers}: direct {i}"));
            assert!(direct.route_status.is_none(), "replicas never push ROUTE-STATUS");
        }

        // The routed handshake mirrors a replica's.
        let mut client = PredictClient::connect(&router.addr().to_string()).unwrap();
        assert_eq!((client.m, client.d), (layout.m, layout.d), "S={servers}: handshake layout");
        assert_eq!(client.version, UPDATES, "S={servers}: handshake fleet version");

        // Solo paths.  Request 1 is a miss on whichever leg P2C drew;
        // by request 3 both legs hold the answer, so requests 3 and 4
        // are cache hits regardless of the draw — and every answer,
        // hit or miss, is bitwise the reference.
        for req in 0..4 {
            expect_prediction(&mut client, &rows, &mean, &var, &format!("S={servers} req {req}"));
        }
        // ROUTE-STATUS was pushed after the handshake and absorbed.
        let (fleet_version, statuses) =
            client.route_status.clone().expect("router pushed ROUTE-STATUS");
        assert_eq!(fleet_version, UPDATES, "S={servers}: advertised fleet version");
        assert_eq!(statuses.len(), 2, "S={servers}: one status per leg");
        for s in &statuses {
            assert_eq!(s.version, UPDATES);
            assert!(!s.retired(), "healthy fleet advertises no retirement");
        }

        // A fresh row set through the same session: forced miss, still
        // bitwise.
        let rows2 = predict_rows(5, layout.d, 123);
        let (mean2, var2) = reference_predict(layout, &run.theta, &rows2);
        expect_prediction(&mut client, &rows2, &mean2, &var2, &format!("S={servers}: fresh rows"));

        drop(client);
        let stats = router.shutdown();
        assert_eq!(stats.routed, 5, "S={servers}: every request answered through the router");
        assert!(stats.cache_hits >= 2, "S={servers}: repeats must hit ({} hits)", stats.cache_hits);
        assert!(stats.cache_misses >= 2, "S={servers}: first touches miss");
        assert_eq!(stats.cache_hits + stats.cache_misses, 5);
        assert!(stats.retired.iter().all(|r| !r), "S={servers}: no leg retired");
        assert_eq!(stats.leg_versions, vec![UPDATES, UPDATES]);
        assert_eq!(
            stats.answered_per_leg.iter().sum::<u64>(),
            stats.routed,
            "S={servers}: per-leg accounting adds up"
        );
        for r in fleet {
            r.shutdown();
        }
    }
}

/// The cross-session batch paths: two concurrent routed sessions (4
/// rows each) against a single replica whose batch server can only
/// flush at `max_rows = 8` (the latency budget is parked 5 s away), so
/// answering *requires* fusing both sessions' rows into one batch —
/// and both sessions' answers are still bitwise the reference for
/// their own rows.  Re-sending the same rows is then answered from the
/// leg's [`AnswerCache`] without the replica ever seeing another
/// batch: `report.batches` stays exactly 1.
#[test]
fn cross_session_requests_fuse_into_one_replica_batch() {
    let (ds, theta0, layout) = setup(300, 5, 53);
    let mut rcfg = ReplicaConfig::default();
    rcfg.batch = BatchConfig { max_rows: 8, latency_budget: Duration::from_secs(5) };
    let (run, mut fleet) = train_fleet(&ds, &theta0, layout, 1, vec![rcfg]);
    let replica = fleet.pop().unwrap();
    assert!(replica.wait_version(UPDATES, Duration::from_secs(30)));
    assert!(replica.wait_trainer_end(Duration::from_secs(30)));
    let router = Router::start(
        "127.0.0.1:0",
        &[replica.predict_addr().to_string()],
        RouterConfig::default(),
    )
    .unwrap();
    let addr = router.addr().to_string();

    let rows_a = predict_rows(4, layout.d, 11);
    let rows_b = predict_rows(4, layout.d, 22);
    let (mean_a, var_a) = reference_predict(layout, &run.theta, &rows_a);
    let (mean_b, var_b) = reference_predict(layout, &run.theta, &rows_b);
    let jobs: [(&[f64], &[f64], &[f64], &str); 2] = [
        (&rows_a, &mean_a, &var_a, "session A"),
        (&rows_b, &mean_b, &var_b, "session B"),
    ];

    // Round 1: both sessions in flight at once — neither can be
    // answered until the other's rows arrive (max_rows short-circuit
    // is the only flush trigger inside the deadline).
    std::thread::scope(|scope| {
        for (rows, mean, var, tag) in jobs {
            let addr = addr.clone();
            scope.spawn(move || {
                let mut c = PredictClient::connect(&addr).unwrap();
                expect_prediction(&mut c, rows, mean, var, tag);
            });
        }
    });
    let warm = router.stats();
    assert_eq!(warm.cache_misses, 2, "both first touches forwarded");
    assert_eq!(warm.cache_hits, 0);

    // Round 2: the same rows again — answered from the answer cache,
    // so the replica's batch count cannot move.
    for (rows, mean, var, tag) in jobs {
        let mut c = PredictClient::connect(&addr).unwrap();
        expect_prediction(&mut c, rows, mean, var, &format!("{tag} (cached)"));
    }
    let stats = router.shutdown();
    assert_eq!(stats.cache_hits, 2, "round 2 never left the router");
    assert_eq!(stats.cache_misses, 2);
    assert_eq!(stats.sessions, 4);

    let report = replica.shutdown();
    assert_eq!(report.batches, 1, "both sessions' rows fused into one replica batch");
    assert_eq!(report.rows, 8, "the fused batch held all 8 rows");
}

/// The failure-domain row: killing one replica mid-session leaves the
/// routed session answering through the sibling with **zero**
/// client-visible errors (fresh rows every request, so the answers
/// provably come from the surviving replica, not the cache), the
/// health probe retires the dead leg, and a fresh session's
/// ROUTE-STATUS advertises the retirement.
#[test]
fn severed_replica_fails_over_to_the_sibling_with_zero_client_errors() {
    let (ds, theta0, layout) = setup(300, 5, 47);
    let (run, mut fleet) =
        train_fleet(&ds, &theta0, layout, 1, vec![ReplicaConfig::default(); 2]);
    for (i, r) in fleet.iter().enumerate() {
        assert!(
            r.wait_version(UPDATES, Duration::from_secs(30)),
            "replica {i} stuck at θ v{:?}",
            r.version()
        );
        assert!(r.wait_trainer_end(Duration::from_secs(30)));
    }
    let addrs: Vec<String> = fleet.iter().map(|r| r.predict_addr().to_string()).collect();
    let mut rcfg = RouterConfig::default();
    // Fast probe cadence so retirement lands inside the test's budget
    // (the probe pings every heartbeat and retires on the first miss).
    // Kept at 2 s — the heartbeat is also the routed session's idle
    // grace, which must comfortably cover the replica-shutdown pause
    // between the healthy and post-sever request bursts below.
    rcfg.retry.heartbeat = Duration::from_secs(2);
    let router = Router::start("127.0.0.1:0", &addrs, rcfg).unwrap();

    let mut client = PredictClient::connect(&router.addr().to_string()).unwrap();
    for i in 0..3u64 {
        let rows = predict_rows(2, layout.d, 500 + i);
        let (mean, var) = reference_predict(layout, &run.theta, &rows);
        expect_prediction(&mut client, &rows, &mean, &var, &format!("healthy req {i}"));
    }

    // Kill replica 0: its listener, sessions, and the router's probe
    // connection all die.
    fleet.remove(0).shutdown();

    // The *same* session keeps answering.  Fresh rows each request
    // force forwarding; any request routed at the dead leg must fail
    // over to the sibling instead of surfacing an error.
    for i in 0..12u64 {
        let rows = predict_rows(2, layout.d, 600 + i);
        let (mean, var) = reference_predict(layout, &run.theta, &rows);
        expect_prediction(&mut client, &rows, &mean, &var, &format!("post-sever req {i}"));
    }
    assert!(
        router.wait_leg_retired(0, Duration::from_secs(15)),
        "probe never retired the dead leg"
    );
    assert!(!router.leg_retired(1), "the survivor stays in rotation");

    // A fresh session is told about the retirement up front.
    let mut fresh = PredictClient::connect(&router.addr().to_string()).unwrap();
    let rows = predict_rows(2, layout.d, 700);
    let (mean, var) = reference_predict(layout, &run.theta, &rows);
    expect_prediction(&mut fresh, &rows, &mean, &var, "fresh session");
    let (fleet_version, statuses) = fresh.route_status.clone().expect("ROUTE-STATUS pushed");
    assert_eq!(fleet_version, UPDATES, "fleet version spans live legs only");
    assert!(statuses[0].retired(), "dead leg advertised as retired");
    assert!(!statuses[1].retired());

    drop(client);
    drop(fresh);
    let stats = router.shutdown();
    assert!(stats.retired[0] && !stats.retired[1]);
    assert_eq!(stats.routed, 16, "3 healthy + 12 post-sever + 1 fresh, all answered");
    assert_eq!(
        stats.answered_per_leg.iter().sum::<u64>(),
        stats.routed,
        "every routed answer is attributed to a leg"
    );
    assert!(
        stats.answered_per_leg[1] >= 13,
        "the survivor carried the post-sever traffic ({:?})",
        stats.answered_per_leg
    );
    fleet.remove(0).shutdown();
}
