//! Chaos: deterministic fault injection (ADVGPFI1, ISSUE 6) against the
//! networked parameter-server fleet.
//!
//! Every test drives real training through a [`FaultProxy`] whose
//! seeded [`FaultPlan`] injects the failures a real network produces —
//! loss, bit rot, congestion delay, duplication, wedged peers, severed
//! links.  The acceptance criteria pinned here:
//!
//! * a seeded fault matrix over {drop, corrupt, delay, duplicate} at
//!   S ∈ {1, 2} slice servers either converges or degrades *typed*
//!   (watchdog / outage-budget exhaustion) — never a hang, never a
//!   panic, never a non-finite θ;
//! * a severed slice link is re-established in place under the
//!   session's outage budget and the run still completes;
//! * a server→worker wedge is detected by the worker-side heartbeat
//!   and resolved by re-establishing the link;
//! * a corrupted push is answered with `ERROR`, counted in
//!   [`ServerStats::faults`], and survived by a reconnect;
//! * the same seed replays the same fault trace, byte for byte.
//!
//! The serving-link matrix (ADVGPSV1, ISSUE 8) extends the same
//! discipline to the read path: a severed or wedged replica
//! *subscription* degrades typed (stale-serve inside the staleness
//! budget, `REJECT(REJ_STALE)` past it), reconnect-with-backoff resumes
//! at the newest θ version, and the same plan replays the same serving
//! fault trace.
//!
//! The routed matrix (ADVGPRT1, ISSUE 9) aims the proxy at a
//! [`Router`]'s predict legs instead: a severed leg drains its
//! sessions to the sibling with zero client-visible errors, a wedged
//! replica is retired by the health probe so P2C stops selecting it,
//! and the same routed seed replays the same routed fault trace.
//!
//! [`ServerStats::faults`]: advgp::ps::metrics::ServerStats

use advgp::data::{kmeans, synth, Dataset, Standardizer};
use advgp::gp::{PredictWorkspace, Theta, ThetaLayout};
use advgp::grad::native_factory;
use advgp::ps::coordinator::{train, train_remote, train_remote_sharded, TrainConfig};
use advgp::ps::fault::Direction;
use advgp::ps::net::{sharded_worker_loop_with, NetServer, ReconnectPolicy, RetryPolicy};
use advgp::ps::wire::{self, Frame};
use advgp::ps::worker::{WorkerProfile, WorkerSource};
use advgp::ps::{FaultEvent, FaultPlan, FaultProxy, FaultRule, RunResult};
use advgp::serve::{PredictAnswer, PredictClient, Replica, ReplicaConfig, Router, RouterConfig};
use advgp::util::rng::Pcg64;
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// Standardized friedman problem + kmeans-initialized θ (the idiom
/// shared with `rust/tests/sharded_ps.rs`).
fn setup(n: usize, m: usize, seed: u64) -> (Dataset, Dataset, Theta, ThetaLayout) {
    let mut ds = synth::friedman(n + 200, 4, 0.4, seed);
    let mut rng = Pcg64::seeded(seed);
    ds.shuffle(&mut rng);
    let (mut train_ds, mut test_ds) = ds.split(200);
    let st = Standardizer::fit(&train_ds);
    st.apply(&mut train_ds);
    st.apply(&mut test_ds);
    let layout = ThetaLayout::new(m, 4);
    let z = kmeans::kmeans(&train_ds.x, m, 15, &mut rng);
    let theta = Theta::init(layout, &z);
    (train_ds, test_ds, theta, layout)
}

fn one_thread() -> WorkerProfile {
    WorkerProfile { threads: 1, ..Default::default() }
}

/// Millisecond-scale budgets so injected outages resolve in test time:
/// fast reconnect backoff, a 250 ms heartbeat (a wedge is detected
/// within ~two windows), and write/handshake bounds far under the
/// watchdog limit.
fn chaos_retry() -> RetryPolicy {
    RetryPolicy {
        reconnect: ReconnectPolicy {
            max_retries: 8,
            base: Duration::from_millis(20),
            cap: Duration::from_millis(200),
        },
        handshake_timeout: Duration::from_secs(5),
        write_timeout: Duration::from_secs(5),
        heartbeat: Duration::from_millis(250),
    }
}

fn chaos_cfg(layout: ThetaLayout, max_updates: u64) -> TrainConfig {
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 2;
    cfg.max_updates = max_updates;
    cfg.eval_every_secs = 0.0;
    cfg.profiles = vec![one_thread(), one_thread()];
    cfg.heartbeat_secs = 0.25;
    // The no-hang backstop: a run that livelocks under faults is shut
    // down typed by the watchdog, and the test still finishes.
    cfg.time_limit_secs = Some(30.0);
    cfg
}

fn assert_finite(theta: &[f64], what: &str) {
    for (i, v) in theta.iter().enumerate() {
        assert!(v.is_finite(), "{what}: θ[{i}] = {v} is not finite");
    }
}

/// Held-out RMSE of a final θ, on the serving stack (the same path
/// `native_eval_factory` uses).
fn rmse_of(layout: ThetaLayout, theta: &[f64], test: &Dataset) -> f64 {
    let cache = advgp::serve::PosteriorCache::new(layout);
    cache.install(1, theta);
    let post = cache.get().expect("posterior installed");
    let mut ws = PredictWorkspace::new();
    let (mut mean, mut var) = (Vec::new(), Vec::new());
    post.gp.predict_into(&test.x, &mut ws, &mut mean, &mut var);
    advgp::util::rmse(&mean, &test.y)
}

/// Run a faulted training session: `s` slice servers, one
/// [`FaultProxy`] per listener (plans in listener order), two workers
/// connecting through the proxies with millisecond chaos budgets.
/// Returns the run result and each proxy's applied-fault trace.
fn run_faulted(
    s: usize,
    layout: ThetaLayout,
    theta0: Vec<f64>,
    shards: Vec<Dataset>,
    plans: Vec<FaultPlan>,
    max_updates: u64,
) -> (RunResult, Vec<Vec<FaultRule>>) {
    assert_eq!(plans.len(), s, "one plan per listener");
    let nets: Vec<NetServer> = (0..s).map(|_| NetServer::bind("127.0.0.1:0").unwrap()).collect();
    let mut proxies: Vec<FaultProxy> = nets
        .iter()
        .zip(plans)
        .map(|(n, plan)| FaultProxy::start(&n.local_addr().to_string(), plan).unwrap())
        .collect();
    let addrs: Vec<String> = proxies.iter().map(|p| p.addr()).collect();
    let workers: Vec<_> = shards
        .into_iter()
        .enumerate()
        .map(|(k, shard)| {
            let addrs = addrs.clone();
            std::thread::spawn(move || {
                // Budget exhaustion under heavy faults is a *typed*
                // error, never a panic — a panic here fails the join.
                let _ = sharded_worker_loop_with(
                    &addrs,
                    Some(k),
                    WorkerSource::Memory(shard),
                    native_factory(layout),
                    one_thread(),
                    chaos_retry(),
                );
            })
        })
        .collect();
    let cfg = chaos_cfg(layout, max_updates);
    let run = if s == 1 {
        train_remote(&cfg, theta0, nets.into_iter().next().unwrap(), 2, None)
    } else {
        train_remote_sharded(&cfg, theta0, nets, 2, None)
    };
    for w in workers {
        w.join().expect("a faulted worker panicked");
    }
    let traces: Vec<Vec<FaultRule>> = proxies.iter().map(|p| p.trace()).collect();
    for p in &mut proxies {
        p.shutdown();
    }
    (run, traces)
}

/// The tentpole matrix: a seeded plan of {drop, delay, duplicate,
/// corrupt} events per listener, at S ∈ {1, 2}.  Every rule is pinned
/// to one of the two *initial* connections (reconnected links get a
/// fresh, clean connection index), so a faulted run recovers instead of
/// replaying the same fault forever.  The run must finish — converged,
/// or typed-degraded by the watchdog — with a finite θ and no panics;
/// when it converges, accuracy must stay within a loose band of the
/// fault-free reference.
#[test]
fn seeded_fault_matrix_converges_or_degrades_typed() {
    let (train_ds, test_ds, theta, layout) = setup(400, 6, 41);
    let shards = train_ds.shard(2);
    let max_updates = 15;

    // Fault-free in-process reference for the accuracy band.
    let base = train(
        &chaos_cfg(layout, max_updates),
        theta.data.clone(),
        shards.clone(),
        native_factory(layout),
        None,
    );
    assert_eq!(base.stats.updates, max_updates);
    let base_rmse = rmse_of(layout, &base.theta, &test_ds);

    let events = [
        FaultEvent::Drop,
        FaultEvent::DelayMs(80),
        FaultEvent::Duplicate,
        FaultEvent::CorruptByte(7),
        FaultEvent::Drop,
        FaultEvent::DelayMs(40),
    ];
    for s in [1usize, 2] {
        let plans: Vec<FaultPlan> = (0..s)
            .map(|i| {
                let seed = 0x5EED_0000 + (s * 16 + i) as u64;
                // Frames 2.. spare the handshake (frame 0 each way) and
                // the first push/publish, so the fleet always assembles
                // before the chaos starts.
                let drawn = FaultPlan::seeded(seed, &events, 2..10);
                // Same seed ⇒ same plan, pinned on every run.
                assert_eq!(drawn, FaultPlan::seeded(seed, &events, 2..10));
                let mut rules = drawn.rules;
                for (j, r) in rules.iter_mut().enumerate() {
                    r.conn = Some(j % 2);
                }
                FaultPlan::new(rules)
            })
            .collect();
        let (run, traces) = run_faulted(
            s,
            layout,
            theta.data.clone(),
            shards.clone(),
            plans,
            max_updates,
        );
        assert_finite(&run.theta, &format!("S={s} faulted"));
        let applied: usize = traces.iter().map(Vec::len).sum();
        assert!(applied >= 1, "S={s}: no fault of the plan was ever applied");
        // Converge-or-typed-degradation: either the run reached its
        // update target, or the watchdog ended it at the wall limit.
        assert!(
            run.stats.updates == max_updates || run.wall_secs >= 29.0,
            "S={s}: run ended early ({} updates in {:.1}s) without a \
             typed degradation path",
            run.stats.updates,
            run.wall_secs
        );
        if run.stats.updates == max_updates {
            let faulted_rmse = rmse_of(layout, &run.theta, &test_ds);
            assert!(
                faulted_rmse <= base_rmse * 1.5 + 0.2,
                "S={s}: faulted RMSE {faulted_rmse:.4} strayed too far from \
                 the fault-free {base_rmse:.4}"
            );
        }
    }
}

/// Half-lost fleet (S=2): severing one worker's link to one slice
/// server mid-run re-establishes only that link, under the session's
/// outage budget — the run still reaches its update target.
#[test]
fn severed_slice_link_reestablishes_under_the_outage_budget() {
    let (train_ds, test_ds, theta, layout) = setup(400, 6, 43);
    let shards = train_ds.shard(2);
    let max_updates = 15;
    let sever = FaultRule {
        conn: Some(0),
        dir: Direction::ServerToClient,
        // s→c frames 0–1 are the WELCOME2 + initial PUBLISH consumed by
        // the handshake; frame 3 lands mid-run.
        frame: 3,
        event: FaultEvent::Sever,
    };
    let plans = vec![FaultPlan::new(vec![sever]), FaultPlan::default()];
    let (run, traces) = run_faulted(2, layout, theta.data.clone(), shards, plans, max_updates);
    assert_eq!(
        run.stats.updates,
        max_updates,
        "the fleet must absorb one severed link and still converge"
    );
    assert_finite(&run.theta, "post-sever");
    assert_eq!(traces[0], vec![sever], "the sever must have been applied");
    assert!(traces[1].is_empty(), "the healthy slice saw no faults");
    let rmse = rmse_of(layout, &run.theta, &test_ds);
    assert!(rmse.is_finite(), "post-sever RMSE {rmse} not finite");
}

/// A wedged server→worker direction (alive at the TCP level, silent at
/// the protocol level) is detected by the worker-side PING/PONG
/// heartbeat and resolved by re-establishing the link.
#[test]
fn wedged_server_link_is_detected_and_reestablished() {
    let (train_ds, _test, theta, layout) = setup(400, 6, 47);
    let shards = train_ds.shard(2);
    let max_updates = 12;
    let wedge = FaultRule {
        conn: Some(0),
        dir: Direction::ServerToClient,
        frame: 4,
        event: FaultEvent::Wedge,
    };
    let plans = vec![FaultPlan::new(vec![wedge])];
    let (run, traces) = run_faulted(1, layout, theta.data.clone(), shards, plans, max_updates);
    assert_eq!(
        run.stats.updates,
        max_updates,
        "a wedged link must be detected and re-established, not waited out"
    );
    assert_finite(&run.theta, "post-wedge");
    assert_eq!(traces[0], vec![wedge]);
}

/// A corrupted worker→server frame is answered with `ERROR`, counted in
/// `ServerStats::faults`, and survived: the worker reconnects and the
/// run converges.
#[test]
fn corrupt_push_counts_a_transport_fault_and_recovers() {
    let (train_ds, _test, theta, layout) = setup(400, 6, 53);
    let shards = train_ds.shard(2);
    let max_updates = 12;
    let corrupt = FaultRule {
        conn: Some(0),
        dir: Direction::ClientToServer,
        // c→s frame 0 is the HELLO; frame 2 is a mid-run push (or PONG)
        // whose checksum the corruption breaks.
        frame: 2,
        event: FaultEvent::CorruptByte(11),
    };
    let plans = vec![FaultPlan::new(vec![corrupt])];
    let (run, traces) = run_faulted(1, layout, theta.data.clone(), shards, plans, max_updates);
    assert_eq!(run.stats.updates, max_updates, "the run must survive the corruption");
    assert_finite(&run.theta, "post-corruption");
    assert_eq!(traces[0], vec![corrupt]);
    assert!(
        run.stats.faults >= 1,
        "the server must have counted the corrupt frame it answered ERROR to \
         (got {} faults)",
        run.stats.faults
    );
}

/// Reproducibility, end to end: the same seed yields the same plan, and
/// replaying that plan over an identical frame schedule applies the
/// identical fault trace — the witness that makes every chaos failure
/// replayable from its seed alone.
#[test]
fn same_seed_replays_the_same_fault_trace() {
    let events = [
        FaultEvent::Drop,
        FaultEvent::CorruptByte(6),
        FaultEvent::DelayMs(30),
        FaultEvent::Duplicate,
        FaultEvent::Drop,
    ];
    // A scripted peer: raw byte echo, so the frame schedule both ways
    // is a pure function of the plan.
    let echo_server = || {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            if let Ok((mut s, _)) = l.accept() {
                let mut buf = [0u8; 4096];
                use std::io::{Read, Write};
                while let Ok(k) = s.read(&mut buf) {
                    if k == 0 || s.write_all(&buf[..k]).is_err() {
                        break;
                    }
                }
            }
        });
        (addr, h)
    };
    let run_once = || -> Vec<FaultRule> {
        let (addr, srv) = echo_server();
        let plan = FaultPlan::seeded(0xABAD_5EED, &events, 0..6);
        let mut proxy = FaultProxy::start(&addr.to_string(), plan).unwrap();
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        for _ in 0..6 {
            wire::write_frame(&mut c, &Frame::Ping).unwrap();
        }
        // Wait for the pumps to drain: the trace is complete once its
        // length is stable (injected delays are ≤ 30 ms; cap the wait).
        let (mut last, mut stable) = (usize::MAX, 0);
        for _ in 0..100 {
            std::thread::sleep(Duration::from_millis(50));
            let n = proxy.trace().len();
            if n == last {
                stable += 1;
                if stable >= 6 {
                    break;
                }
            } else {
                (last, stable) = (n, 0);
            }
        }
        let trace = proxy.trace();
        drop(c);
        proxy.shutdown();
        let _ = srv.join();
        trace
    };
    let first = run_once();
    let second = run_once();
    assert!(!first.is_empty(), "the seeded plan must have applied faults");
    assert_eq!(first, second, "same seed must replay the same fault trace");
}

// ---------------------------------------------------------------------
// ADVGPSV1 serving links (ISSUE 8): the same chaos discipline aimed at
// a replica's posterior subscription instead of a worker's push stream.
// The training fleet stays healthy (workers dial the server directly);
// only the read path runs through the proxy, so every assertion is
// about *serving* degradation, never about convergence.
// ---------------------------------------------------------------------

/// A read-path chaos session with a *recoverable* plan: trainer first
/// (its accept loop answers the subscription), then the replica through
/// the fault proxy, then — only once every planned fault has fired
/// (idle heartbeats drive the frame clock) and the link has had a
/// moment to finish its repair — the workers.  Holding the workers back
/// makes the fault schedule deterministic: the run cannot finish, and
/// the publish stream cannot shut down, before the chaos has played
/// out.  Returns the proxy's applied-fault trace and the θ version a
/// post-recovery PREDICT reports.
fn run_served_recovery(
    plan: FaultPlan,
    expect_applied: usize,
    seed: u64,
) -> (Vec<FaultRule>, u64) {
    let (train_ds, _test, theta, layout) = setup(400, 6, seed);
    let shards = train_ds.shard(2);
    let max_updates = 12u64;
    let net = NetServer::bind("127.0.0.1:0").unwrap();
    let addr = net.local_addr().to_string();
    let mut proxy = FaultProxy::start(&addr, plan).unwrap();
    let sub_addr = proxy.addr();
    let trainer = {
        let theta0 = theta.data.clone();
        std::thread::spawn(move || {
            train_remote(&chaos_cfg(layout, max_updates), theta0, net, 2, None)
        })
    };
    let replica = Replica::start(
        "127.0.0.1:0",
        std::slice::from_ref(&sub_addr),
        ReplicaConfig { retry: chaos_retry(), ..Default::default() },
    )
    .expect("replica subscribes through the proxy");
    let deadline = Instant::now() + Duration::from_secs(15);
    while proxy.trace().len() < expect_applied {
        assert!(
            Instant::now() < deadline,
            "planned serving faults never fired (trace: {:?})",
            proxy.trace()
        );
        std::thread::sleep(Duration::from_millis(25));
    }
    // One more beat: the last fault has fired but the reconnect behind
    // it (a few tens of ms of backoff) may still be in flight.
    std::thread::sleep(Duration::from_secs(1));
    let workers: Vec<_> = shards
        .into_iter()
        .enumerate()
        .map(|(k, shard)| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let _ = sharded_worker_loop_with(
                    &[addr],
                    Some(k),
                    WorkerSource::Memory(shard),
                    native_factory(layout),
                    one_thread(),
                    chaos_retry(),
                );
            })
        })
        .collect();
    let run = trainer.join().expect("trainer thread");
    for w in workers {
        w.join().expect("worker thread");
    }
    assert_eq!(
        run.stats.updates, max_updates,
        "training is healthy — only the read path is faulted"
    );
    assert!(
        replica.wait_version(max_updates, Duration::from_secs(30)),
        "replica never resumed to θ v{max_updates} after the outage \
         (stuck at {:?})",
        replica.version()
    );
    assert!(
        replica.wait_trainer_end(Duration::from_secs(10)),
        "the clean SHUTDOWN never reached the replica"
    );
    let mut client = PredictClient::connect(&replica.predict_addr().to_string())
        .expect("predict session after recovery");
    let version = match client.predict(&[0.3, -0.1, 0.25, -0.6]).expect("predict") {
        PredictAnswer::Prediction { version, .. } => version,
        PredictAnswer::Rejected { code, message } => {
            panic!("recovered replica rejected (code {code}: {message})")
        }
    };
    assert_eq!(
        replica.rejects().total(),
        0,
        "an outage repaired inside the staleness budget must not reject"
    );
    drop(client);
    let _ = replica.shutdown();
    let trace = proxy.trace();
    proxy.shutdown();
    (trace, version)
}

/// An unrecoverable subscription outage degrades *typed*: the replica
/// stale-serves its last posterior inside the staleness budget, then
/// answers `REJECT(REJ_STALE)` — and the predict session survives the
/// rejects instead of being dropped.
#[test]
fn severed_subscription_stale_serves_then_rejects_typed() {
    let (train_ds, _test, theta, layout) = setup(400, 6, 59);
    let shards = train_ds.shard(2);
    let max_updates = 12u64;
    // conn 0 (the initial subscription) loses its stream right after
    // the handshake; every reconnect attempt (conns 1..) is severed
    // during *its* handshake, so the outage outlives the reconnect
    // budget (8 attempts) and the staleness clock runs out.
    let mut rules = vec![FaultRule {
        conn: Some(0),
        dir: Direction::ServerToClient,
        frame: 1,
        event: FaultEvent::Sever,
    }];
    for c in 1..=9 {
        rules.push(FaultRule {
            conn: Some(c),
            dir: Direction::ServerToClient,
            frame: 0,
            event: FaultEvent::Sever,
        });
    }
    let conn0_sever = rules[0];
    let net = NetServer::bind("127.0.0.1:0").unwrap();
    let addr = net.local_addr().to_string();
    let mut proxy = FaultProxy::start(&addr, FaultPlan::new(rules)).unwrap();
    let sub_addr = proxy.addr();
    let trainer = {
        let theta0 = theta.data.clone();
        std::thread::spawn(move || {
            train_remote(&chaos_cfg(layout, max_updates), theta0, net, 2, None)
        })
    };
    let replica = Replica::start(
        "127.0.0.1:0",
        std::slice::from_ref(&sub_addr),
        ReplicaConfig {
            staleness_budget: Duration::from_millis(400),
            retry: chaos_retry(),
            ..Default::default()
        },
    )
    .expect("replica subscribes through the proxy");

    // Predict continuously across the sever.  The sequence must be:
    // Predictions (fresh, then stale-within-budget) … then REJ_STALE.
    let mut client = PredictClient::connect(&replica.predict_addr().to_string())
        .expect("predict session");
    let rows = [0.2, -0.4, 0.6, -0.8];
    let deadline = Instant::now() + Duration::from_secs(20);
    let mut served = 0u64;
    let (code, message) = loop {
        assert!(
            Instant::now() < deadline,
            "REJ_STALE never arrived ({served} predictions answered)"
        );
        match client.predict(&rows).expect("session must survive the outage") {
            PredictAnswer::Prediction { .. } => served += 1,
            PredictAnswer::Rejected { code, message } => break (code, message),
        }
        std::thread::sleep(Duration::from_millis(25));
    };
    assert_eq!(code, wire::REJ_STALE, "wrong reject: code {code} ({message})");
    assert!(
        served >= 1,
        "the replica must stale-serve within the budget before rejecting"
    );
    // REJECT is per-request, not a session fault: the same session's
    // next predict draws another typed verdict, not a dead socket.
    match client.predict(&rows).expect("session alive after REJECT") {
        PredictAnswer::Rejected { code, .. } => assert_eq!(code, wire::REJ_STALE),
        PredictAnswer::Prediction { .. } => {
            panic!("the link cannot repair — every reconnect is severed")
        }
    }
    assert!(replica.rejects().total() >= 2, "reject tallies must record the verdicts");

    // The training fleet was never touched: release the workers and the
    // run completes normally.
    let workers: Vec<_> = shards
        .into_iter()
        .enumerate()
        .map(|(k, shard)| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let _ = sharded_worker_loop_with(
                    &[addr],
                    Some(k),
                    WorkerSource::Memory(shard),
                    native_factory(layout),
                    one_thread(),
                    chaos_retry(),
                );
            })
        })
        .collect();
    let run = trainer.join().expect("trainer thread");
    for w in workers {
        w.join().expect("worker thread");
    }
    assert_eq!(run.stats.updates, max_updates);
    assert_finite(&run.theta, "read-path chaos");
    let trace = proxy.trace();
    assert!(
        trace.contains(&conn0_sever),
        "the subscription sever must be in the trace: {trace:?}"
    );
    assert!(
        trace.len() >= 2,
        "at least one reconnect handshake must also have been severed: {trace:?}"
    );
    drop(client);
    let _ = replica.shutdown();
    proxy.shutdown();
}

/// A severed subscription repaired inside the staleness budget:
/// reconnect-with-backoff survives a second sever during the first
/// retry's handshake, resumes at the newest θ the server holds, and the
/// replica ends the run at the trainer's final version with zero
/// rejects.
#[test]
fn severed_subscription_reconnects_and_resumes_at_newest_theta() {
    let sever0 = FaultRule {
        conn: Some(0),
        dir: Direction::ServerToClient,
        frame: 1,
        event: FaultEvent::Sever,
    };
    let sever1 = FaultRule {
        conn: Some(1),
        dir: Direction::ServerToClient,
        frame: 0,
        event: FaultEvent::Sever,
    };
    let (trace, version) =
        run_served_recovery(FaultPlan::new(vec![sever0, sever1]), 2, 61);
    assert_eq!(trace, vec![sever0, sever1]);
    assert_eq!(version, 12, "post-recovery predicts must report the final θ version");
}

/// A wedged subscription (TCP-alive, protocol-silent) is detected by
/// the replica-side PING/PONG heartbeat within ~two windows and
/// resolved by re-establishing the link.
#[test]
fn wedged_subscription_is_detected_by_heartbeat_and_repaired() {
    let wedge = FaultRule {
        conn: Some(0),
        dir: Direction::ServerToClient,
        frame: 1,
        event: FaultEvent::Wedge,
    };
    let (trace, version) = run_served_recovery(FaultPlan::new(vec![wedge]), 1, 67);
    assert_eq!(trace, vec![wedge]);
    assert_eq!(version, 12);
}

/// Serving-link replay determinism: a plan whose sever frame is *drawn
/// from a seed* (pinned to the subscription's publish stream, the
/// serving-chaos direction) applies the identical fault trace on two
/// independent end-to-end runs — same seed, same serving chaos.
#[test]
fn same_seed_replays_the_same_serving_fault_trace() {
    let drawn = FaultPlan::seeded(0x5EED_5E12, &[FaultEvent::Sever], 1..4);
    assert_eq!(
        drawn,
        FaultPlan::seeded(0x5EED_5E12, &[FaultEvent::Sever], 1..4),
        "same seed must yield the same plan"
    );
    let mut rules = drawn.rules;
    for r in rules.iter_mut() {
        // Serving chaos lives on the server→replica publish stream of
        // the initial subscription; frames 1.. spare the handshake.
        r.conn = Some(0);
        r.dir = Direction::ServerToClient;
    }
    rules.push(FaultRule {
        conn: Some(1),
        dir: Direction::ServerToClient,
        frame: 0,
        event: FaultEvent::Sever,
    });
    let plan = FaultPlan::new(rules);
    let (first, v1) = run_served_recovery(plan.clone(), 2, 71);
    let (second, v2) = run_served_recovery(plan, 2, 71);
    assert!(!first.is_empty(), "the seeded serving plan must have applied faults");
    assert_eq!(first, second, "same seed must replay the same serving fault trace");
    assert_eq!((v1, v2), (12, 12));
}

// ---------------------------------------------------------------------
// ADVGPRT1 routed serving (ISSUE 9): the chaos discipline aimed at a
// router's predict legs.  Training runs to completion *before* the
// router starts — every assertion here is about the routed read path
// (failover, retirement, replay), never about convergence.
//
// Seeds in use (documented per the chaos discipline):
// * 0x5EED_5E13 — the seeded routed sever plan (replay row) and the
//   RouterConfig::seed / request-stream seed of that row;
// * 0xF01D_0001 / 0xF01D_0002 — request-stream seeds of the failover
//   and wedge rows (the router P2C seed stays at its default there).
// ---------------------------------------------------------------------

/// Train a healthy single-server run to completion with `replicas`
/// subscribed replicas, wait every replica to the final θ and the
/// clean trainer end, and hand the fleet over — chaos is then applied
/// to the predict path only.
fn trained_fleet(seed: u64, replicas: usize) -> (RunResult, Vec<Replica>, ThetaLayout) {
    let (train_ds, _test, theta, layout) = setup(400, 6, seed);
    let shards = train_ds.shard(2);
    let max_updates = 12u64;
    let net = NetServer::bind("127.0.0.1:0").unwrap();
    let addr = net.local_addr().to_string();
    let trainer = {
        let theta0 = theta.data.clone();
        std::thread::spawn(move || {
            train_remote(&chaos_cfg(layout, max_updates), theta0, net, 2, None)
        })
    };
    let fleet: Vec<Replica> = (0..replicas)
        .map(|_| {
            Replica::start(
                "127.0.0.1:0",
                std::slice::from_ref(&addr),
                ReplicaConfig { retry: chaos_retry(), ..Default::default() },
            )
            .expect("replica subscribes")
        })
        .collect();
    let workers: Vec<_> = shards
        .into_iter()
        .enumerate()
        .map(|(k, shard)| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let _ = sharded_worker_loop_with(
                    &[addr],
                    Some(k),
                    WorkerSource::Memory(shard),
                    native_factory(layout),
                    one_thread(),
                    chaos_retry(),
                );
            })
        })
        .collect();
    let run = trainer.join().expect("trainer thread");
    for w in workers {
        w.join().expect("worker thread");
    }
    assert_eq!(run.stats.updates, max_updates, "the training fleet is healthy");
    for (i, r) in fleet.iter().enumerate() {
        assert!(
            r.wait_version(max_updates, Duration::from_secs(30)),
            "replica {i} stuck at θ v{:?}",
            r.version()
        );
        assert!(r.wait_trainer_end(Duration::from_secs(10)));
    }
    (run, fleet, layout)
}

fn fresh_rows(rng: &mut Pcg64, d: usize) -> Vec<f64> {
    (0..d).map(|_| rng.next_f64() * 2.0 - 1.0).collect()
}

/// Severing a replica's predict leg mid-session drains the session to
/// the sibling inside the router's retry budget: every request —
/// including the one whose answer the sever swallowed — comes back as
/// a PREDICTION, zero client-visible errors.  No probe retirement is
/// involved: the leg stays live and the next request simply redials a
/// clean connection.
#[test]
fn severed_predict_leg_fails_over_with_zero_client_visible_errors() {
    let (run, fleet, layout) = trained_fleet(73, 2);
    // Proxy conns in accept order: 0 = the router's validation dial
    // (adopted by the health probe), 1 = the first session leg.  Sever
    // the leg's server→client stream at its second answer frame
    // (frame 0 is the handshake ack) — i.e. mid-session.
    let sever = FaultRule {
        conn: Some(1),
        dir: Direction::ServerToClient,
        frame: 2,
        event: FaultEvent::Sever,
    };
    let mut proxy = FaultProxy::start(
        &fleet[0].predict_addr().to_string(),
        FaultPlan::new(vec![sever]),
    )
    .unwrap();
    let legs = vec![proxy.addr(), fleet[1].predict_addr().to_string()];
    // Cache off: every request must actually forward, so the sever is
    // guaranteed to be exercised by live traffic.
    let rcfg = RouterConfig { cache_rows: 0, ..Default::default() };
    let router = Router::start("127.0.0.1:0", &legs, rcfg).unwrap();
    let mut client = PredictClient::connect(&router.addr().to_string()).unwrap();
    let mut rng = Pcg64::seeded(0xF01D_0001);
    let deadline = Instant::now() + Duration::from_secs(20);
    let mut answered = 0u64;
    loop {
        let rows = fresh_rows(&mut rng, layout.d);
        match client.predict(&rows).expect("session must survive the sever") {
            PredictAnswer::Prediction { version, .. } => {
                assert_eq!(version, run.stats.updates, "answers stay at the final θ");
                answered += 1;
            }
            PredictAnswer::Rejected { code, message } => {
                panic!("client-visible error across the sever ({code}: {message})")
            }
        }
        // Keep going until the sever has fired *and* enough later
        // answers prove the session outlived it.
        if !proxy.trace().is_empty() && answered >= 24 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "the planned sever never fired (trace {:?}, {answered} answered)",
            proxy.trace()
        );
    }
    assert_eq!(proxy.trace(), vec![sever]);
    drop(client);
    let stats = router.shutdown();
    assert_eq!(stats.routed, answered, "every request answered through the router");
    assert!(stats.failovers >= 1, "the dead leg connection must have failed over");
    assert!(
        stats.surfaced_rejects.iter().all(|&(_, n)| n == 0),
        "nothing surfaced to the client: {:?}",
        stats.surfaced_rejects
    );
    assert!(!stats.retired[0], "a severed connection is not a retired leg");
    for r in fleet {
        r.shutdown();
    }
    proxy.shutdown();
}

/// A wedged replica (TCP-alive, protocol-silent) is detected by the
/// router's health probe within ~two heartbeat windows and the leg is
/// retired: P2C stops selecting it, sessions opened after the
/// retirement never touch it, and ROUTE-STATUS advertises the
/// retirement.
#[test]
fn wedged_replica_is_retired_and_p2c_stops_selecting_it() {
    let (run, fleet, layout) = trained_fleet(79, 2);
    // conn 0 (the router's validation dial, adopted by the probe)
    // wedges server→client after the handshake ack (frame 0): the
    // probe's first PING draws no PONG and its read times out.  Every
    // probe *reconnect* (conns 1..) is severed during its handshake so
    // a revival cannot race the assertions below.
    let mut rules = vec![FaultRule {
        conn: Some(0),
        dir: Direction::ServerToClient,
        frame: 1,
        event: FaultEvent::Wedge,
    }];
    for c in 1..=40 {
        rules.push(FaultRule {
            conn: Some(c),
            dir: Direction::ServerToClient,
            frame: 0,
            event: FaultEvent::Sever,
        });
    }
    let mut proxy =
        FaultProxy::start(&fleet[0].predict_addr().to_string(), FaultPlan::new(rules))
            .unwrap();
    let legs = vec![proxy.addr(), fleet[1].predict_addr().to_string()];
    let rcfg =
        RouterConfig { retry: chaos_retry(), cache_rows: 0, ..Default::default() };
    let router = Router::start("127.0.0.1:0", &legs, rcfg).unwrap();
    assert!(
        router.wait_leg_retired(0, Duration::from_secs(10)),
        "the heartbeat probe never retired the wedged leg"
    );
    // A session opened after the retirement: P2C must never select the
    // wedged leg, so every answer is prompt and error-free.
    let mut client = PredictClient::connect(&router.addr().to_string()).unwrap();
    let mut rng = Pcg64::seeded(0xF01D_0002);
    for i in 0..10 {
        let rows = fresh_rows(&mut rng, layout.d);
        match client.predict(&rows).expect("session") {
            PredictAnswer::Prediction { version, .. } => {
                assert_eq!(version, run.stats.updates)
            }
            PredictAnswer::Rejected { code, message } => {
                panic!("request {i} rejected behind a retired leg ({code}: {message})")
            }
        }
    }
    let (_, statuses) = client.route_status.clone().expect("ROUTE-STATUS pushed");
    assert!(statuses[0].retired(), "the wedged leg must be advertised retired");
    assert!(!statuses[1].retired());
    drop(client);
    let stats = router.shutdown();
    assert!(stats.retired[0] && !stats.retired[1]);
    assert_eq!(
        stats.answered_per_leg[0], 0,
        "a retired leg must receive no session traffic"
    );
    assert_eq!(
        stats.failovers, 0,
        "retirement prevents failover churn entirely — P2C never tried the leg"
    );
    assert!(!proxy.trace().is_empty(), "the wedge must have fired");
    for r in fleet {
        r.shutdown();
    }
    proxy.shutdown();
}

/// Routed replay determinism: a sever plan *drawn from seed
/// 0x5EED_5E13*, pinned to the first session leg's server→client
/// stream, applies the identical fault trace on two independent
/// end-to-end routed runs — same seed, same P2C draws, same routed
/// chaos — and both runs answer every request.
#[test]
fn same_seed_replays_the_same_routed_fault_trace() {
    fn routed_faulted_run(plan: FaultPlan) -> Vec<FaultRule> {
        let (run, fleet, layout) = trained_fleet(83, 2);
        let mut proxy =
            FaultProxy::start(&fleet[0].predict_addr().to_string(), plan).unwrap();
        let legs = vec![proxy.addr(), fleet[1].predict_addr().to_string()];
        // Default 30 s heartbeat: no idle-leg redials and no probe
        // repings inside the run, so the proxy's conn/frame schedule is
        // a pure function of the session's P2C draws — which the fixed
        // router seed pins.
        let rcfg =
            RouterConfig { cache_rows: 0, seed: 0x5EED_5E13, ..Default::default() };
        let router = Router::start("127.0.0.1:0", &legs, rcfg).unwrap();
        let mut client = PredictClient::connect(&router.addr().to_string()).unwrap();
        let mut rng = Pcg64::seeded(0x5EED_5E13);
        for i in 0..24 {
            let rows = fresh_rows(&mut rng, layout.d);
            match client.predict(&rows).expect("session survives the routed chaos") {
                PredictAnswer::Prediction { version, .. } => {
                    assert_eq!(version, run.stats.updates)
                }
                PredictAnswer::Rejected { code, message } => {
                    panic!("request {i} surfaced an error ({code}: {message})")
                }
            }
        }
        drop(client);
        let stats = router.shutdown();
        assert_eq!(stats.routed, 24, "every request answered");
        let trace = proxy.trace();
        for r in fleet {
            r.shutdown();
        }
        proxy.shutdown();
        trace
    }
    let drawn = FaultPlan::seeded(0x5EED_5E13, &[FaultEvent::Sever], 1..4);
    assert_eq!(
        drawn,
        FaultPlan::seeded(0x5EED_5E13, &[FaultEvent::Sever], 1..4),
        "same seed must yield the same plan"
    );
    let mut rules = drawn.rules;
    for r in rules.iter_mut() {
        // conn 1 = the first session leg (conn 0 is the probe); frames
        // 1.. spare the handshake ack.
        r.conn = Some(1);
        r.dir = Direction::ServerToClient;
    }
    let plan = FaultPlan::new(rules);
    let first = routed_faulted_run(plan.clone());
    let second = routed_faulted_run(plan);
    assert!(!first.is_empty(), "the seeded routed plan must have applied faults");
    assert_eq!(first, second, "same seed must replay the same routed fault trace");
}
