//! Integration: out-of-core shard store, checkpoint/restore, and
//! elastic membership (ISSUE 3) — training end-to-end from on-disk
//! shards, exact resume, and mid-run worker join.  ISSUE 7 extends the
//! exact-resume contract to *streamed* stores: per-worker `(offset,
//! local_iter)` cursors ride in the checkpoint, so τ=0 resume is
//! bitwise even when windows are smaller than shards.

use advgp::data::store::{ShardReader, ShardSet};
use advgp::data::{kmeans, synth, Dataset, Standardizer};
use advgp::gp::{SparseGp, Theta, ThetaLayout};
use advgp::grad::{native_factory, EngineFactory, GradEngine, GradResult};
use advgp::linalg::Mat;
use advgp::ps::coordinator::{
    train, train_elastic, train_sources, Joiner, TrainConfig,
};
use advgp::ps::worker::{WorkerProfile, WorkerSource};
use advgp::ps::{Checkpoint, Published};
use advgp::util::rmse;
use advgp::util::rng::Pcg64;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn tdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("advgp_sc_test").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Standardized friedman problem + kmeans-initialized θ.
fn setup(n: usize, m: usize, seed: u64) -> (Dataset, Dataset, Theta, ThetaLayout) {
    let mut ds = synth::friedman(n + 200, 4, 0.4, seed);
    let mut rng = Pcg64::seeded(seed);
    ds.shuffle(&mut rng);
    let (mut train_ds, mut test_ds) = ds.split(200);
    let st = Standardizer::fit(&train_ds);
    st.apply(&mut train_ds);
    st.apply(&mut test_ds);
    let layout = ThetaLayout::new(m, 4);
    let z = kmeans::kmeans(&train_ds.x, m, 15, &mut rng);
    let theta = Theta::init(layout, &z);
    (train_ds, test_ds, theta, layout)
}

fn mean_rmse(test: &Dataset) -> f64 {
    rmse(&vec![0.0; test.n()], &test.y)
}

fn store_sources(set: &ShardSet) -> Vec<WorkerSource> {
    set.readers()
        .unwrap()
        .into_iter()
        .map(WorkerSource::Store)
        .collect()
}

/// Workers streaming minibatch chunks from on-disk shards must converge
/// just like resident-shard workers — the tentpole end-to-end path.
#[test]
fn store_backed_training_converges() {
    let dir = tdir("train");
    let (train_ds, test_ds, theta, layout) = setup(2000, 16, 1);
    // Chunks well below the ~667-row shards: every gradient is a true
    // streamed minibatch (with wrap-around), not a disguised full batch.
    let set = ShardSet::create(&dir, &train_ds, 3, 256).unwrap();
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 8;
    cfg.max_updates = 400;
    cfg.eval_every_secs = 0.0;
    let res = train_sources(
        &cfg,
        theta.data.clone(),
        store_sources(&set),
        native_factory(layout),
        None,
    );
    assert_eq!(res.stats.updates, 400);
    let gp = SparseGp::new(Theta { layout, data: res.theta });
    let (mean, _) = gp.predict(&test_ds.x);
    let final_rmse = rmse(&mean, &test_ds.y);
    let baseline = mean_rmse(&test_ds);
    assert!(
        final_rmse < 0.7 * baseline,
        "rmse {final_rmse} vs mean predictor {baseline}"
    );
}

/// A store-fed worker's minibatch windows must tile its whole shard
/// (same coverage contract as the in-memory cyclic window).
#[test]
fn store_worker_covers_whole_shard() {
    use std::collections::HashSet;

    struct Probe {
        layout: ThetaLayout,
        chunk: usize,
        seen: Arc<Mutex<HashSet<i64>>>,
    }
    impl GradEngine for Probe {
        fn layout(&self) -> ThetaLayout {
            self.layout
        }
        fn grad(&mut self, _theta: &[f64], x: &Mat, _y: &[f64]) -> GradResult {
            assert_eq!(x.rows, self.chunk, "window must be exactly the chunk");
            let mut seen = self.seen.lock().unwrap();
            for i in 0..x.rows {
                seen.insert(x.row(i)[0].round() as i64);
            }
            GradResult { value: 0.0, grad: vec![0.0; self.layout.len()] }
        }
        fn name(&self) -> &'static str {
            "probe"
        }
    }

    let dir = tdir("coverage");
    let n = 30usize;
    let chunk = 8usize;
    let layout = ThetaLayout::new(2, 1);
    let shard = Dataset {
        x: Mat::from_vec(n, 1, (0..n).map(|i| i as f64).collect()),
        y: vec![0.0; n],
    };
    let set = ShardSet::create(&dir, &shard, 1, chunk).unwrap();
    let seen = Arc::new(Mutex::new(HashSet::new()));
    let seen_f = Arc::clone(&seen);
    let factory: EngineFactory = Arc::new(move |_worker| {
        Box::new(Probe { layout, chunk, seen: Arc::clone(&seen_f) })
    });
    let z0 = Mat::from_vec(2, 1, vec![3.0, 20.0]);
    let theta = Theta::init(layout, &z0);
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 32;
    cfg.max_updates = 12; // ≥ ⌈30/8⌉ = 4 worker iterations needed
    cfg.eval_every_secs = 0.0;
    train_sources(&cfg, theta.data.clone(), store_sources(&set), factory, None);
    let seen = seen.lock().unwrap();
    let missing: Vec<usize> = (0..n).filter(|i| !seen.contains(&(*i as i64))).collect();
    assert!(
        missing.is_empty(),
        "store worker never saw rows {missing:?} (saw {} of {n})",
        seen.len()
    );
}

/// The first θ any worker pulls after a resume must be the checkpointed
/// θ, bitwise — verified race-free at the worker's first gradient call
/// (the server cannot update before every worker has pushed once).
#[test]
fn resume_republishes_checkpoint_theta_bitwise() {
    let ckdir = tdir("bitwise_ck");
    let (train_ds, _test, theta, layout) = setup(600, 8, 3);

    // Leg 1: 40 updates, checkpointing every 10.
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 4;
    cfg.max_updates = 40;
    cfg.eval_every_secs = 0.0;
    cfg.checkpoint_every = 10;
    cfg.checkpoint_dir = Some(ckdir.clone());
    train(
        &cfg,
        theta.data.clone(),
        train_ds.shard(2),
        native_factory(layout),
        None,
    );
    let ck = Checkpoint::load_latest(&ckdir).unwrap().expect("leg 1 checkpointed");
    assert_eq!(ck.version, 40, "final checkpoint seals the run");
    assert_eq!(ck.clocks.len(), 2);

    // Leg 2: resume; a probe wrapping the native engine records the
    // first θ each worker is handed.
    struct FirstTheta {
        inner: Box<dyn GradEngine>,
        recorded: bool,
        sink: Arc<Mutex<Vec<Vec<f64>>>>,
    }
    impl GradEngine for FirstTheta {
        fn layout(&self) -> ThetaLayout {
            self.inner.layout()
        }
        fn grad(&mut self, theta: &[f64], x: &Mat, y: &[f64]) -> GradResult {
            if !self.recorded {
                self.recorded = true;
                self.sink.lock().unwrap().push(theta.to_vec());
            }
            self.inner.grad(theta, x, y)
        }
        fn name(&self) -> &'static str {
            "first-theta-probe"
        }
    }
    let firsts: Arc<Mutex<Vec<Vec<f64>>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = Arc::clone(&firsts);
    let native = native_factory(layout);
    let factory: EngineFactory = Arc::new(move |worker| {
        Box::new(FirstTheta {
            inner: native(worker),
            recorded: false,
            sink: Arc::clone(&sink),
        })
    });
    let mut cfg2 = TrainConfig::new(layout);
    cfg2.tau = 4;
    cfg2.max_updates = 60;
    cfg2.eval_every_secs = 0.0;
    cfg2.resume_from = Some(ck.clone());
    let res = train(
        &cfg2,
        theta.data.clone(), // deliberately stale: the checkpoint must win
        train_ds.shard(2),
        factory,
        None,
    );
    assert_eq!(res.stats.updates, 60, "cumulative ceiling: 40 resumed → 60");
    let firsts = firsts.lock().unwrap();
    assert_eq!(firsts.len(), 2, "both workers recorded a first pull");
    for (w, th) in firsts.iter().enumerate() {
        assert_eq!(th.len(), ck.theta.len());
        for (a, b) in th.iter().zip(&ck.theta) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "worker {w}: first pulled θ differs from checkpoint"
            );
        }
    }
}

/// Determinism under τ=0: N updates + checkpoint + resume to 2N must
/// land bitwise on the same θ as 2N updates straight through — the
/// checkpoint captures *everything* the trajectory depends on.
#[test]
fn resumed_trajectory_matches_uninterrupted_run_bitwise() {
    let ckdir = tdir("traj");
    let (train_ds, _test, theta, layout) = setup(400, 6, 11);
    let run = |max: u64, every: u64, resume: Option<Checkpoint>| {
        let mut cfg = TrainConfig::new(layout);
        cfg.tau = 0; // sync: aggregation identical every update
        cfg.max_updates = max;
        cfg.eval_every_secs = 0.0;
        cfg.checkpoint_every = every;
        cfg.checkpoint_dir = (every > 0).then(|| ckdir.clone());
        cfg.resume_from = resume;
        train(
            &cfg,
            theta.data.clone(),
            train_ds.shard(2),
            native_factory(layout),
            None,
        )
    };
    let direct = run(30, 0, None);
    let _leg1 = run(15, 15, None);
    let ck = Checkpoint::load_latest(&ckdir).unwrap().unwrap();
    assert_eq!(ck.version, 15);
    let resumed = run(30, 0, Some(ck));
    assert_eq!(resumed.stats.updates, 30);
    for (i, (a, b)) in direct.theta.iter().zip(&resumed.theta).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "θ[{i}] diverged: straight {a} vs resumed {b}"
        );
    }
}

/// Checkpoint cadence: every N updates plus a sealing checkpoint at the
/// end, all loadable, newest wins.
#[test]
fn checkpoint_cadence_and_seal() {
    let ckdir = tdir("cadence");
    let (train_ds, _test, theta, layout) = setup(400, 6, 5);
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 4;
    cfg.max_updates = 35;
    cfg.eval_every_secs = 0.0;
    cfg.checkpoint_every = 10;
    cfg.checkpoint_dir = Some(ckdir.clone());
    train(
        &cfg,
        theta.data.clone(),
        train_ds.shard(2),
        native_factory(layout),
        None,
    );
    let mut versions: Vec<u64> = std::fs::read_dir(&ckdir)
        .unwrap()
        .map(|e| Checkpoint::load(&e.unwrap().path()).unwrap().version)
        .collect();
    versions.sort_unstable();
    // Cadence writes are async and may individually be skipped while a
    // previous save is in flight, but every file must sit on a cadence
    // boundary (or be the seal), and the synchronous final seal at
    // t=35 is guaranteed.
    assert!(
        versions.iter().all(|v| [10, 20, 30, 35].contains(v)),
        "off-cadence checkpoint files: {versions:?}"
    );
    assert_eq!(versions.last(), Some(&35), "final seal missing: {versions:?}");
    assert_eq!(Checkpoint::load_latest(&ckdir).unwrap().unwrap().version, 35);
}

/// Checkpoint GC (ISSUE 4 satellite): with `keep_last` set, cadence
/// writes prune as they land and the run never retains more than K
/// files — while the final seal always survives and still resumes.
#[test]
fn checkpoint_cadence_prunes_to_keep_last() {
    let ckdir = tdir("gc");
    let (train_ds, _test, theta, layout) = setup(400, 6, 5);
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 4;
    cfg.max_updates = 35;
    cfg.eval_every_secs = 0.0;
    cfg.checkpoint_every = 10;
    cfg.checkpoint_dir = Some(ckdir.clone());
    cfg.keep_last = Some(2);
    train(
        &cfg,
        theta.data.clone(),
        train_ds.shard(2),
        native_factory(layout),
        None,
    );
    let files = Checkpoint::list_in(&ckdir).unwrap();
    assert!(
        (1..=2).contains(&files.len()),
        "keep_last=2 retained {} files: {files:?}",
        files.len()
    );
    let mut versions: Vec<u64> = files
        .iter()
        .map(|p| Checkpoint::load(p).unwrap().version)
        .collect();
    versions.sort_unstable();
    // Survivors still sit on cadence boundaries (or are the seal), and
    // the newest is always the t=35 seal a resume would want.
    assert!(
        versions.iter().all(|v| [10, 20, 30, 35].contains(v)),
        "off-cadence survivors: {versions:?}"
    );
    assert_eq!(versions.last(), Some(&35), "seal pruned away: {versions:?}");
    let ck = Checkpoint::load_latest(&ckdir).unwrap().unwrap();
    assert_eq!(ck.version, 35);
    // The survivor is a valid resume point.
    let mut cfg2 = TrainConfig::new(layout);
    cfg2.tau = 4;
    cfg2.max_updates = 40;
    cfg2.eval_every_secs = 0.0;
    cfg2.resume_from = Some(ck);
    let res = train(
        &cfg2,
        theta.data.clone(),
        train_ds.shard(2),
        native_factory(layout),
        None,
    );
    assert_eq!(res.stats.updates, 40, "resume from the GC survivor");
}

/// A worker that joins mid-run is admitted on its first push and
/// contributes to convergence; ids/gaps never stall the gate.
#[test]
fn late_joiner_is_admitted() {
    let (train_ds, test_ds, theta, layout) = setup(1000, 10, 7);
    let shards = train_ds.shard(3);
    let mut shards = shards.into_iter();
    let s0 = shards.next().unwrap();
    let s1 = shards.next().unwrap();
    let s2 = shards.next().unwrap();
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 16;
    cfg.max_updates = 150;
    cfg.eval_every_secs = 0.0;
    // Slow the initial workers slightly so the run outlives the join.
    cfg.profiles = vec![
        WorkerProfile { straggle: Duration::from_millis(2), ..Default::default() },
        WorkerProfile { straggle: Duration::from_millis(2), ..Default::default() },
    ];
    let res = train_elastic(
        &cfg,
        Published::new(theta.data.clone()),
        vec![WorkerSource::Memory(s0), WorkerSource::Memory(s1)],
        vec![Joiner {
            after: Duration::from_millis(40),
            source: WorkerSource::Memory(s2),
            profile: WorkerProfile::default(),
        }],
        native_factory(layout),
        None,
    );
    assert_eq!(res.stats.updates, 150);
    assert_eq!(res.stats.joins, 1, "joiner admitted on first push");
    let gp = SparseGp::new(Theta { layout, data: res.theta });
    let (mean, _) = gp.predict(&test_ds.x);
    assert!(rmse(&mean, &test_ds.y) < 0.8 * mean_rmse(&test_ds));
}

/// Handover: every initial worker departs *before* the declared joiner
/// arrives.  The server must keep the run open for the outstanding
/// joiner (`ServerConfig::expected_joiners`) instead of ending at the
/// moment the live set empties, and the joiner alone finishes the run.
#[test]
fn run_survives_full_handover_to_late_joiner() {
    let (train_ds, _test, theta, layout) = setup(400, 6, 17);
    let shards = train_ds.shard(2);
    let mut shards = shards.into_iter();
    let s0 = shards.next().unwrap();
    let s1 = shards.next().unwrap();
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 8;
    cfg.max_updates = 40;
    cfg.eval_every_secs = 0.0;
    cfg.profiles = vec![WorkerProfile { leave_at: Some(3), ..Default::default() }];
    let res = train_elastic(
        &cfg,
        Published::new(theta.data.clone()),
        vec![WorkerSource::Memory(s0)],
        vec![Joiner {
            // Long after the lone initial worker (3 fast iterations) is
            // gone: without expected_joiners the run would end early.
            after: Duration::from_millis(150),
            source: WorkerSource::Memory(s1),
            profile: WorkerProfile::default(),
        }],
        native_factory(layout),
        None,
    );
    assert_eq!(res.stats.updates, 40, "joiner must finish the run alone");
    assert_eq!(res.stats.joins, 1);
    assert!(res.stats.leaves >= 1);
}

/// Store readers hand workers bitwise-identical data to the resident
/// path: a τ=0 sync run from disk matches the in-memory run exactly
/// when windows align (chunk = shard size).
#[test]
fn store_and_memory_runs_agree_bitwise_when_windows_align() {
    let dir = tdir("parity");
    let (train_ds, _test, theta, layout) = setup(300, 6, 13);
    let shards = train_ds.shard(2);
    let max_shard = shards.iter().map(|s| s.n()).max().unwrap();
    let set = ShardSet::create(&dir, &train_ds, 2, max_shard).unwrap();
    let run = |sources: Vec<WorkerSource>| {
        let mut cfg = TrainConfig::new(layout);
        cfg.tau = 0;
        cfg.max_updates = 20;
        cfg.eval_every_secs = 0.0;
        // chunk = full shard: store workers stream one n_k-row window
        // from offset 0 (full-shard windows are never offset-seeded),
        // i.e. the same rows in the same order the memory workers
        // borrow — so the gradients, and hence every θ update, must be
        // bitwise identical.
        train_sources(&cfg, theta.data.clone(), sources, native_factory(layout), None)
    };
    let mem = run(shards.into_iter().map(WorkerSource::Memory).collect());
    let disk = run(store_sources(&set));
    for (i, (a, b)) in mem.theta.iter().zip(&disk.theta).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "θ[{i}]: store vs memory diverged");
    }
}

/// Reader streaming is allocation-free in steady state and resident
/// data is one chunk: the window buffers never grow past chunk size.
#[test]
fn worker_residency_is_one_chunk() {
    let dir = tdir("residency");
    let ds = synth::friedman(512, 4, 0.2, 2);
    let set = ShardSet::create(&dir, &ds, 1, 32).unwrap();
    let mut r: ShardReader = set.reader(0).unwrap();
    let mut win = Dataset { x: Mat::empty(), y: Vec::new() };
    for _ in 0..20 {
        r.next_window(&mut win).unwrap();
    }
    let stride = (ds.d() + 1) * 8;
    assert!(
        r.buf_capacity() <= 2 * 32 * stride,
        "byte buffer {} exceeds chunk scale",
        r.buf_capacity()
    );
    assert!(win.x.data.capacity() <= 2 * 32 * ds.d(), "x window grew past chunk");
    assert!(win.y.capacity() <= 2 * 32, "y window grew past chunk");
    let (cb, cx, cy) = (r.buf_capacity(), win.x.data.capacity(), win.y.capacity());
    for _ in 0..100 {
        r.next_window(&mut win).unwrap();
    }
    assert_eq!(
        (r.buf_capacity(), win.x.data.capacity(), win.y.capacity()),
        (cb, cx, cy),
        "steady-state minibatch path allocated"
    );
}

/// The checkpoint lineage manifest (ISSUE 5 satellite): every run that
/// seals appends one `(run_id, resumed_from, step, wall_time)` record
/// to `lineage.json`, chained across resumes, and the manifest survives
/// keep-last-K GC (which touches only `ck_*.bin`).
#[test]
fn lineage_manifest_chains_runs_and_survives_gc() {
    use advgp::ps::checkpoint::{self, LINEAGE_MANIFEST};
    let ckdir = tdir("lineage");
    let (train_ds, _test, theta, layout) = setup(300, 6, 51);
    let shards = train_ds.shard(2);
    let run = |max: u64, resume: Option<Checkpoint>| {
        let mut cfg = TrainConfig::new(layout);
        cfg.tau = 0;
        cfg.max_updates = max;
        cfg.eval_every_secs = 0.0;
        cfg.profiles = vec![
            WorkerProfile { threads: 1, ..Default::default() },
            WorkerProfile { threads: 1, ..Default::default() },
        ];
        cfg.checkpoint_every = 4;
        cfg.checkpoint_dir = Some(ckdir.clone());
        cfg.keep_last = Some(2);
        cfg.resume_from = resume;
        train(&cfg, theta.data.clone(), shards.clone(), native_factory(layout), None)
    };

    // Fresh run to 8: one record, no parent.
    run(8, None);
    let records = checkpoint::read_lineage(&ckdir).unwrap();
    assert_eq!(records.len(), 1);
    assert_eq!(records[0].resumed_from, None);
    assert_eq!(records[0].step, 8);
    assert!(records[0].wall_secs >= 0.0);

    // Resume to 16: second record, chained to the v8 seal.
    let ck = Checkpoint::load_latest(&ckdir).unwrap().expect("sealed");
    assert_eq!(ck.version, 8);
    run(16, Some(ck));
    let records = checkpoint::read_lineage(&ckdir).unwrap();
    assert_eq!(records.len(), 2, "one record per completed run");
    assert_eq!(records[1].resumed_from, Some(8));
    assert_eq!(records[1].step, 16);
    assert_ne!(records[0].run_id, records[1].run_id, "distinct runs, distinct ids");

    // GC prunes checkpoint files only — the manifest (and the newest
    // seal) survive an aggressive keep=1 pass.
    Checkpoint::prune_keep_last(&ckdir, 1).unwrap();
    assert!(ckdir.join(LINEAGE_MANIFEST).is_file(), "lineage survives GC");
    assert_eq!(Checkpoint::load_latest(&ckdir).unwrap().unwrap().version, 16);
    assert_eq!(checkpoint::read_lineage(&ckdir).unwrap().len(), 2);

    // Provenance rendering: one line per run, chained.
    let prov = checkpoint::provenance(&ckdir).unwrap();
    assert!(prov.contains("fresh") && prov.contains("resumed from v8"), "{prov}");
    assert!(prov.contains(&records[0].run_id) && prov.contains(&records[1].run_id));
}

/// ISSUE 7's acceptance pin: τ=0 resume of a *streamed* store run is
/// bitwise end-to-end even when windows are smaller than shards.  The
/// checkpoint's per-worker `(offset, local_iter)` cursors put every
/// resumed reader exactly where the uninterrupted run's reader would
/// be; without them the resumed workers would restart their streams and
/// feed different windows from update 16 on.
#[test]
fn streamed_resume_matches_uninterrupted_run_bitwise() {
    let sdir = tdir("stream_traj_store");
    let ckdir = tdir("stream_traj_ck");
    let (train_ds, _test, theta, layout) = setup(400, 6, 11);
    // Chunks well below the 200-row shards: windows wrap mid-shard, so
    // the trajectory genuinely depends on where each stream stands.
    let set = ShardSet::create(&sdir, &train_ds, 2, 64).unwrap();
    let run = |max: u64, every: u64, resume: Option<Checkpoint>| {
        let mut cfg = TrainConfig::new(layout);
        cfg.tau = 0; // sync: aggregation identical every update
        cfg.max_updates = max;
        cfg.eval_every_secs = 0.0;
        cfg.checkpoint_every = every;
        cfg.checkpoint_dir = (every > 0).then(|| ckdir.clone());
        cfg.resume_from = resume;
        train_sources(
            &cfg,
            theta.data.clone(),
            store_sources(&set),
            native_factory(layout),
            None,
        )
    };
    let direct = run(30, 0, None);
    let _leg1 = run(15, 15, None);
    let ck = Checkpoint::load_latest(&ckdir).unwrap().unwrap();
    assert_eq!(ck.version, 15);
    assert_eq!(ck.cursors.len(), 2, "both stream cursors sealed");
    for &(_w, _off, windows) in &ck.cursors {
        assert_eq!(windows, 15, "τ=0 lockstep: 15 windows per worker");
    }
    let resumed = run(30, 0, Some(ck));
    assert_eq!(resumed.stats.updates, 30);
    for (i, (a, b)) in direct.theta.iter().zip(&resumed.theta).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "θ[{i}] diverged: straight {a} vs streamed-resumed {b}"
        );
    }
}

/// Skip-on-corrupt for sharded checkpoint directories (ISSUE 7
/// satellite): when one slice's newest file is corrupt,
/// `load_latest_sharded` falls back to the newest version *every* slice
/// can still reassemble instead of failing the resume.
#[test]
fn sharded_resume_skips_version_with_corrupt_slice() {
    let ckdir = tdir("sharded_corrupt");
    let (train_ds, _test, theta, layout) = setup(400, 6, 19);
    let run = |max: u64, resume: Option<Checkpoint>| {
        let mut cfg = TrainConfig::new(layout);
        cfg.servers = 2;
        cfg.tau = 4;
        cfg.max_updates = max;
        cfg.eval_every_secs = 0.0;
        cfg.profiles = vec![
            WorkerProfile { threads: 1, ..Default::default() },
            WorkerProfile { threads: 1, ..Default::default() },
        ];
        // Cadence == max: exactly one synchronous seal per leg, so both
        // slices are guaranteed the same two versions across the legs.
        cfg.checkpoint_every = max;
        cfg.checkpoint_dir = Some(ckdir.clone());
        cfg.resume_from = resume;
        train(
            &cfg,
            theta.data.clone(),
            train_ds.shard(2),
            native_factory(layout),
            None,
        )
    };
    run(20, None);
    let ck20 = Checkpoint::load_latest_sharded(&ckdir).unwrap().unwrap();
    assert_eq!(ck20.version, 20);
    run(35, Some(ck20.clone()));
    assert_eq!(
        Checkpoint::load_latest_sharded(&ckdir).unwrap().unwrap().version,
        35
    );
    // Scribble slice 1's v35 file: that version can no longer be
    // reassembled, and the loader must fall back to v20 — the newest
    // version still intact in *every* slice.
    let bad = ckdir.join("slice_01_of_02").join("ck_000000000035.bin");
    assert!(bad.is_file(), "expected slice seal at {}", bad.display());
    std::fs::write(&bad, b"not a checkpoint").unwrap();
    let fell_back = Checkpoint::load_latest_sharded(&ckdir).unwrap().unwrap();
    assert_eq!(fell_back.version, 20, "newest common intact version wins");
    // The fallback is the same state the v20 seal held.
    assert_eq!(fell_back.theta.len(), ck20.theta.len());
    for (a, b) in fell_back.theta.iter().zip(&ck20.theta) {
        assert_eq!(a.to_bits(), b.to_bits(), "fallback must be the v20 state");
    }
}

/// Lineage round-trips through an empty/missing directory gracefully.
#[test]
fn lineage_reads_empty_when_absent() {
    use advgp::ps::checkpoint;
    let dir = tdir("lineage_absent");
    assert!(checkpoint::read_lineage(&dir).unwrap().is_empty());
    assert_eq!(checkpoint::provenance(&dir).unwrap(), "");
    // And appending to a not-yet-created directory creates it.
    let missing = dir.join("nested");
    checkpoint::append_lineage(
        &missing,
        checkpoint::LineageRecord {
            run_id: "abc123".into(),
            resumed_from: Some(5),
            step: 9,
            wall_secs: 1.25,
        },
    )
    .unwrap();
    let records = checkpoint::read_lineage(&missing).unwrap();
    assert_eq!(records.len(), 1);
    assert_eq!(records[0].resumed_from, Some(5));
    assert_eq!(records[0].run_id, "abc123");
}
