//! Integration: the four baselines and ADVGP on one shared problem —
//! relative orderings the paper's evaluation depends on.

use advgp::experiments::methods::*;
use advgp::experiments::{flight_problem, taxi_problem};

#[test]
fn all_methods_beat_mean_on_flight() {
    let p = flight_problem(6_000, 1_000, 25, 3);
    let opts = MethodOpts { budget_secs: 4.0, ..Default::default() };
    let sync = MethodOpts { budget_secs: 4.0, tau: 0, ..Default::default() };
    let mean = final_rmse(&run_mean_method(&p));
    for (name, r) in [
        ("advgp", run_advgp(&p, &opts)),
        ("svigp", run_svigp_method(&p, &opts)),
        ("distgp-gd", run_distgp_gd_method(&p, &sync)),
        ("distgp-lbfgs", run_distgp_lbfgs_method(&p, &sync)),
        ("linear", run_linear_method(&p, &opts)),
    ] {
        let rmse = final_rmse(&r);
        assert!(rmse < mean, "{name}: {rmse} !< mean {mean}");
        assert!(!r.trace.is_empty(), "{name}: empty trace");
    }
}

#[test]
fn gp_beats_linear_on_taxi_shape() {
    // Fig. 4's qualitative content at test scale.
    let p = taxi_problem(6_000, 1_000, 25, 5);
    let opts = MethodOpts { budget_secs: 5.0, tau: 20, ..Default::default() };
    let gp = final_rmse(&run_advgp(&p, &opts));
    let lin = final_rmse(&run_linear_method(&p, &opts));
    let mean = final_rmse(&run_mean_method(&p));
    assert!(gp < lin, "GP {gp} !< linear {lin}");
    assert!(lin < mean, "linear {lin} !< mean {mean}");
}

#[test]
fn advgp_and_svigp_reach_similar_quality() {
    // Tables 1–2's "comparable accuracy" claim: within 15% of each other
    // given equal budget at small scale.
    let p = flight_problem(6_000, 1_000, 25, 7);
    let opts = MethodOpts { budget_secs: 6.0, ..Default::default() };
    let a = final_rmse(&run_advgp(&p, &opts));
    let s = final_rmse(&run_svigp_method(&p, &opts));
    let ratio = a / s;
    assert!((0.8..1.25).contains(&ratio), "advgp {a} vs svigp {s} (ratio {ratio})");
}

#[test]
fn async_does_more_updates_than_sync_with_stragglers() {
    // Fig. 3's mechanism: under heterogeneous workers the async gate
    // sustains far more server updates per second than the τ=0 barrier.
    let p = flight_problem(4_000, 500, 16, 9);
    let mk = |tau: u64| MethodOpts {
        budget_secs: 3.0,
        tau,
        workers: 4,
        straggle_ms: vec![0, 5, 10, 20],
        eval_every_secs: 10.0, // don't let eval interfere
        ..Default::default()
    };
    let async_r = run_advgp(&p, &mk(64));
    let sync_r = run_advgp(&p, &mk(0));
    let au = async_r.trace.last().map(|t| t.version).unwrap_or(0);
    let su = sync_r.trace.last().map(|t| t.version).unwrap_or(0);
    assert!(
        au as f64 > 1.5 * su as f64,
        "async {au} updates vs sync {su} — expected a clear gap"
    );
}
