//! Integration: the ADVGPNT1 networked parameter-server transport
//! (ISSUE 4) — wire-codec robustness against a live server, loopback-
//! TCP training runs, bitwise parity with the in-process path at τ=0,
//! mid-stream disconnect retirement, and networked checkpoint/resume
//! with keep-last GC.

use advgp::data::{kmeans, synth, Dataset, Standardizer};
use advgp::gp::{Theta, ThetaLayout};
use advgp::grad::native_factory;
use advgp::ps::coordinator::{train, train_remote, TrainConfig};
use advgp::ps::net::{remote_worker_loop, NetServer, NetWorkerHandle};
use advgp::ps::wire::{
    self, Frame, ERR_ID_IN_USE, ERR_MALFORMED, ERR_PROTO, PROTO_NT1, PROTO_NT2,
    PROTO_VERSION,
};
use advgp::ps::worker::{WorkerProfile, WorkerSource};
use advgp::ps::{Checkpoint, PublishMeta};
use advgp::util::rng::Pcg64;
use std::net::TcpStream;
use std::path::PathBuf;

fn tdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("advgp_net_test").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Standardized friedman problem + kmeans-initialized θ.
fn setup(n: usize, m: usize, seed: u64) -> (Dataset, Dataset, Theta, ThetaLayout) {
    let mut ds = synth::friedman(n + 200, 4, 0.4, seed);
    let mut rng = Pcg64::seeded(seed);
    ds.shuffle(&mut rng);
    let (mut train_ds, mut test_ds) = ds.split(200);
    let st = Standardizer::fit(&train_ds);
    st.apply(&mut train_ds);
    st.apply(&mut test_ds);
    let layout = ThetaLayout::new(m, 4);
    let z = kmeans::kmeans(&train_ds.x, m, 15, &mut rng);
    let theta = Theta::init(layout, &z);
    (train_ds, test_ds, theta, layout)
}

/// Fixed per-worker thread budgets: the gradient engine's lane
/// reduction is deterministic *per budget*, so bitwise comparisons pin
/// every worker to one lane on both transports.
fn one_thread() -> WorkerProfile {
    WorkerProfile { threads: 1, ..Default::default() }
}

/// The acceptance-criterion test: a 2-worker τ=0 training run over
/// loopback TCP must reproduce the in-process θ trajectory **bitwise**
/// — the transport moves the same messages the channel would, and the
/// server aggregates slots in worker-id order either way.
#[test]
fn loopback_tcp_matches_in_process_bitwise_at_tau0() {
    let (train_ds, _test, theta, layout) = setup(400, 6, 11);
    let shards = train_ds.shard(2);
    let mk_cfg = || {
        let mut cfg = TrainConfig::new(layout);
        cfg.tau = 0;
        cfg.max_updates = 25;
        cfg.eval_every_secs = 0.0;
        cfg.profiles = vec![one_thread(), one_thread()];
        cfg
    };

    // In-process reference.
    let cfg = mk_cfg();
    let local = train(
        &cfg,
        theta.data.clone(),
        shards.clone(),
        native_factory(layout),
        None,
    );
    assert_eq!(local.stats.updates, 25);

    // Loopback-TCP twin: same shards, same ids, same thread budgets.
    let net = NetServer::bind("127.0.0.1:0").unwrap();
    let addr = net.local_addr().to_string();
    let workers: Vec<_> = shards
        .into_iter()
        .enumerate()
        .map(|(k, shard)| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                remote_worker_loop(
                    &addr,
                    Some(k),
                    WorkerSource::Memory(shard),
                    native_factory(layout),
                    one_thread(),
                )
                .unwrap()
            })
        })
        .collect();
    let cfg = mk_cfg();
    let remote = train_remote(&cfg, theta.data.clone(), net, 2, None);
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(remote.stats.updates, 25);
    assert_eq!(remote.stats.joins, 0, "declared workers are not joins");
    for (i, (a, b)) in local.theta.iter().zip(&remote.theta).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "θ[{i}] diverged: in-process {a} vs loopback-TCP {b}"
        );
    }
}

/// A networked run that checkpoints (with keep-last GC), is killed, and
/// resumes over the network must land bitwise on the θ of an
/// uninterrupted in-process run — durability and transport compose.
#[test]
fn networked_checkpoint_resume_matches_uninterrupted_run_bitwise() {
    let ckdir = tdir("net_resume");
    let (train_ds, _test, theta, layout) = setup(300, 6, 13);
    let shards = train_ds.shard(2);
    let remote_run = |max: u64, every: u64, resume: Option<Checkpoint>| {
        let mut cfg = TrainConfig::new(layout);
        cfg.tau = 0;
        cfg.max_updates = max;
        cfg.eval_every_secs = 0.0;
        cfg.checkpoint_every = every;
        cfg.checkpoint_dir = (every > 0).then(|| ckdir.clone());
        cfg.keep_last = (every > 0).then_some(2);
        cfg.resume_from = resume;
        let net = NetServer::bind("127.0.0.1:0").unwrap();
        let addr = net.local_addr().to_string();
        let workers: Vec<_> = shards
            .clone()
            .into_iter()
            .enumerate()
            .map(|(k, shard)| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    remote_worker_loop(
                        &addr,
                        Some(k),
                        WorkerSource::Memory(shard),
                        native_factory(layout),
                        one_thread(),
                    )
                    .unwrap()
                })
            })
            .collect();
        let res = train_remote(&cfg, theta.data.clone(), net, 2, None);
        for w in workers {
            w.join().unwrap();
        }
        res
    };

    // Leg 1: 15 updates over TCP, checkpoint every 5, keep the last 2.
    let leg1 = remote_run(15, 5, None);
    assert_eq!(leg1.stats.updates, 15);
    let files = Checkpoint::list_in(&ckdir).unwrap();
    assert!(
        files.len() <= 2,
        "keep_last=2 retained {} files: {files:?}",
        files.len()
    );
    let ck = Checkpoint::load_latest(&ckdir).unwrap().expect("leg 1 sealed");
    assert_eq!(ck.version, 15, "seal is the newest survivor");

    // Leg 2: resume over TCP to 30.
    let resumed = remote_run(30, 0, Some(ck));
    assert_eq!(resumed.stats.updates, 30);

    // Uninterrupted in-process reference.
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 0;
    cfg.max_updates = 30;
    cfg.eval_every_secs = 0.0;
    cfg.profiles = vec![one_thread(), one_thread()];
    let direct = train(&cfg, theta.data.clone(), shards, native_factory(layout), None);
    for (i, (a, b)) in direct.theta.iter().zip(&resumed.theta).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "θ[{i}] diverged: uninterrupted {a} vs networked-resumed {b}"
        );
    }
}

/// A remote worker whose connection dies mid-stream — no EXIT frame,
/// just EOF — must have its clock retired via the gate so the
/// survivors finish the run (the networked twin of the in-process
/// kill-worker test).  τ=2 means a lingering clock would stall the run
/// within 3 updates.
#[test]
fn mid_stream_disconnect_retires_clock_via_gate() {
    let (train_ds, _test, theta, layout) = setup(600, 8, 7);
    let shards = train_ds.shard(2);
    let net = NetServer::bind("127.0.0.1:0").unwrap();
    let addr = net.local_addr().to_string();

    // Two well-behaved remote workers own the real shards.
    let workers: Vec<_> = shards
        .into_iter()
        .enumerate()
        .map(|(k, shard)| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                remote_worker_loop(
                    &addr,
                    Some(k),
                    WorkerSource::Memory(shard),
                    native_factory(layout),
                    one_thread(),
                )
                .unwrap()
            })
        })
        .collect();

    // The flaky third member: handshakes as worker 2 — speaking
    // revision 1, which a rev-2 single-slice server must still serve —
    // pushes one all-zero gradient, then vanishes without an EXIT frame.
    let flaky = {
        let addr = addr.clone();
        let dim = layout.len();
        std::thread::spawn(move || {
            let mut s = TcpStream::connect(&addr).unwrap();
            wire::write_frame(
                &mut s,
                &Frame::Hello { proto: PROTO_NT1, worker: 2 },
            )
            .unwrap();
            let mut scratch = Vec::new();
            match wire::read_frame(&mut s, &mut scratch).unwrap() {
                Frame::Welcome { worker, m, d, .. } => {
                    assert_eq!(worker, 2);
                    assert_eq!((m as usize, d as usize), (layout.m, layout.d));
                }
                f => panic!("expected WELCOME, got {f:?}"),
            }
            let version = match wire::read_frame(&mut s, &mut scratch).unwrap() {
                Frame::Publish { version, theta, .. } => {
                    assert_eq!(theta.len(), dim);
                    version
                }
                f => panic!("expected PUBLISH, got {f:?}"),
            };
            let push = advgp::ps::messages::Push {
                worker: 2,
                version,
                value: 0.0,
                grad: vec![0.0; dim],
                compute_secs: 0.0,
            };
            wire::write_frame(&mut s, &Frame::Push(push)).unwrap();
            // Drop the socket: a kill -9, not a polite departure.
        })
    };

    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 2;
    cfg.max_updates = 60;
    cfg.eval_every_secs = 0.0;
    cfg.time_limit_secs = Some(60.0); // hang backstop only; never hit
    let res = train_remote(&cfg, theta.data.clone(), net, 3, None);
    flaky.join().unwrap();
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(
        res.stats.updates, 60,
        "survivors must finish the run after the disconnect"
    );
    assert!(res.stats.leaves >= 1, "the EOF must be observed as a departure");
    // Staleness stays bounded for the live membership throughout.
    assert!(res.stats.staleness.max <= cfg.tau as f64);
}

/// Handshake rejections: wrong protocol revision and duplicate worker
/// ids get ERROR frames (and the server survives to serve real
/// clients); id auto-assignment hands out the lowest free id.
#[test]
fn handshake_rejects_bad_proto_and_duplicate_ids() {
    let (_train, _test, theta, layout) = setup(200, 4, 3);
    let net = NetServer::bind("127.0.0.1:0").unwrap();
    let addr = net.local_addr().to_string();

    let server = {
        let mut cfg = TrainConfig::new(layout);
        cfg.tau = 0;
        cfg.max_updates = 10;
        cfg.eval_every_secs = 0.0;
        cfg.time_limit_secs = Some(60.0);
        let theta0 = theta.data.clone();
        std::thread::spawn(move || train_remote(&cfg, theta0, net, 1, None))
    };

    // A legitimate connection holding worker id 0 (never pushes).
    let held = NetWorkerHandle::connect(&addr, Some(0)).unwrap();
    assert_eq!(held.worker, 0);
    assert_eq!(held.version(), 0);

    // Duplicate id → ERR_ID_IN_USE surfaced through connect().
    let err = NetWorkerHandle::connect(&addr, Some(0)).unwrap_err();
    assert!(
        err.to_string().contains(&format!("code {ERR_ID_IN_USE}")),
        "want id-in-use rejection, got: {err:#}"
    );

    // Auto-assign starts above the declared range (R = 1 here), so an
    // ANY connection can never squat a declared gate id.
    let auto = NetWorkerHandle::connect(&addr, None).unwrap();
    assert_eq!(auto.worker, 1, "lowest free id ≥ declared worker count");

    // Version negotiation: a client offering a *future* revision is
    // negotiated down to the server's highest (min(offer, ours) = 2),
    // not rejected — forward compatibility by construction.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        wire::write_frame(&mut s, &Frame::Hello { proto: 99, worker: 7 }).unwrap();
        let mut scratch = Vec::new();
        match wire::read_frame(&mut s, &mut scratch).unwrap() {
            Frame::Welcome2 { proto, worker, .. } => {
                assert_eq!(proto, PROTO_NT2, "negotiated down to rev 2");
                assert_eq!(worker, 7);
            }
            f => panic!("expected WELCOME2 at rev 2, got {f:?}"),
        }
    }

    // An unknown *lower* revision (0) has no framing we can speak →
    // ERR_PROTO error frame.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        wire::write_frame(&mut s, &Frame::Hello { proto: 0, worker: 8 }).unwrap();
        let mut scratch = Vec::new();
        match wire::read_frame(&mut s, &mut scratch).unwrap() {
            Frame::Error { code, .. } => assert_eq!(code, ERR_PROTO),
            f => panic!("expected ERROR, got {f:?}"),
        }
    }

    // Implausible id claim → ERR_MALFORMED, never an allocation: the
    // server's gate clocks and gradient slots are id-indexed arrays.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        wire::write_frame(
            &mut s,
            &Frame::Hello { proto: PROTO_VERSION, worker: 1 << 40 },
        )
        .unwrap();
        let mut scratch = Vec::new();
        match wire::read_frame(&mut s, &mut scratch).unwrap() {
            Frame::Error { code, .. } => assert_eq!(code, ERR_MALFORMED),
            f => panic!("expected ERROR, got {f:?}"),
        }
    }

    // Drop both held connections: their clocks retire (id 0 was the
    // only declared worker), so the run ends without a single update.
    drop(held);
    drop(auto);
    let res = server.join().unwrap();
    assert_eq!(res.stats.updates, 0, "nobody ever pushed a gradient");
}

/// Post-handshake protocol-state enforcement: a mismatched push id, a
/// wrong-dimension gradient, and a PUSH after EXIT each draw the
/// specified ERROR frame, drop the connection, and — critically —
/// leave the gate with the clock retired so the run ends instead of
/// stalling on a ghost member.
#[test]
fn protocol_violations_get_errors_and_retire_the_clock() {
    let (_train, _test, theta, layout) = setup(200, 4, 21);
    let dim = layout.len();

    // Handshake as worker 0 (revision 1 — the violation handling must
    // be revision-agnostic) and return the stream + handshake version.
    let connect = |addr: &str| -> (TcpStream, u64) {
        let mut s = TcpStream::connect(addr).unwrap();
        wire::write_frame(&mut s, &Frame::Hello { proto: PROTO_NT1, worker: 0 })
            .unwrap();
        let mut scratch = Vec::new();
        match wire::read_frame(&mut s, &mut scratch).unwrap() {
            Frame::Welcome { worker: 0, .. } => {}
            f => panic!("expected WELCOME for worker 0, got {f:?}"),
        }
        match wire::read_frame(&mut s, &mut scratch).unwrap() {
            Frame::Publish { version, .. } => (s, version),
            f => panic!("expected PUBLISH, got {f:?}"),
        }
    };
    // Read until the ERROR frame (publishes/shutdowns may interleave).
    let expect_error = |s: &mut TcpStream, want_code: u16| {
        let mut scratch = Vec::new();
        loop {
            match wire::read_frame(s, &mut scratch).unwrap() {
                Frame::Error { code, message } => {
                    assert_eq!(code, want_code, "unexpected error: {message}");
                    return;
                }
                Frame::Publish { .. } | Frame::Shutdown => continue,
                f => panic!("expected ERROR {want_code}, got {f:?}"),
            }
        }
    };
    let serve = |max_updates: u64| {
        let net = NetServer::bind("127.0.0.1:0").unwrap();
        let addr = net.local_addr().to_string();
        let mut cfg = TrainConfig::new(layout);
        cfg.tau = 0;
        cfg.max_updates = max_updates;
        cfg.eval_every_secs = 0.0;
        cfg.time_limit_secs = Some(20.0); // stall backstop; never hit
        let theta0 = theta.data.clone();
        (addr, std::thread::spawn(move || train_remote(&cfg, theta0, net, 1, None)))
    };
    let push = |worker: usize, version: u64, grad_dim: usize| {
        Frame::Push(advgp::ps::messages::Push {
            worker,
            version,
            value: 0.0,
            grad: vec![0.0; grad_dim],
            compute_secs: 0.0,
        })
    };

    // Mismatched id → code 6; the never-admitted clock retires and the
    // run ends without a single update.
    let (addr, server) = serve(5);
    let (mut s, v) = connect(&addr);
    wire::write_frame(&mut s, &push(1, v, dim)).unwrap();
    expect_error(&mut s, wire::ERR_ID_MISMATCH);
    drop(s);
    assert_eq!(server.join().unwrap().stats.updates, 0);

    // Wrong gradient dimension → code 5; same retirement.
    let (addr, server) = serve(5);
    let (mut s, v) = connect(&addr);
    wire::write_frame(&mut s, &push(0, v, dim + 1)).unwrap();
    expect_error(&mut s, wire::ERR_DIM);
    drop(s);
    assert_eq!(server.join().unwrap().stats.updates, 0);

    // PUSH after EXIT → code 4, and the clock STAYS retired: exactly
    // one update (from the valid pre-EXIT push) ever lands.
    let (addr, server) = serve(5);
    let (mut s, v) = connect(&addr);
    wire::write_frame(&mut s, &push(0, v, dim)).unwrap();
    // Wait for the resulting publish before EXITing: sent back-to-back,
    // PUSH and EXIT can drain in one server absorb cycle — the clock
    // retires and the slot clears before the gate ever permits, and no
    // update would land at all.
    let mut scratch = Vec::new();
    loop {
        match wire::read_frame(&mut s, &mut scratch).unwrap() {
            Frame::Publish { version, .. } if version > v => break,
            Frame::Publish { .. } => continue,
            f => panic!("expected PUBLISH v{}, got {f:?}", v + 1),
        }
    }
    wire::write_frame(&mut s, &Frame::WorkerExit { worker: 0 }).unwrap();
    wire::write_frame(&mut s, &push(0, v + 1, dim)).unwrap();
    expect_error(&mut s, wire::ERR_MALFORMED);
    drop(s);
    let res = server.join().unwrap();
    assert_eq!(res.stats.updates, 1, "post-EXIT push must not re-admit");
    assert!(res.stats.leaves >= 1);
}

/// A serve-ps run nobody joins must still honor its wall-clock limit —
/// the transport keeps its channel sender open for the whole run, so
/// the server loop has to observe shutdown, not channel disconnect.
#[test]
fn unjoined_run_respects_time_limit() {
    let (_train, _test, theta, layout) = setup(200, 4, 5);
    let net = NetServer::bind("127.0.0.1:0").unwrap();
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 0;
    cfg.max_updates = 100;
    cfg.eval_every_secs = 0.0;
    cfg.time_limit_secs = Some(0.3);
    let start = std::time::Instant::now();
    let res = train_remote(&cfg, theta.data.clone(), net, 2, None);
    assert!(start.elapsed() < std::time::Duration::from_secs(20));
    assert_eq!(res.stats.updates, 0);
}

/// PUBLISH frames carry the gate-clock metadata of the aggregation
/// that produced them: a remote observer sees live count and staleness
/// without any side channel.
#[test]
fn publish_frames_carry_clock_metadata() {
    let (train_ds, _test, theta, layout) = setup(300, 6, 9);
    let shards = train_ds.shard(2);
    let net = NetServer::bind("127.0.0.1:0").unwrap();
    let addr = net.local_addr().to_string();
    let workers: Vec<_> = shards
        .into_iter()
        .enumerate()
        .map(|(k, shard)| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                remote_worker_loop(
                    &addr,
                    Some(k),
                    WorkerSource::Memory(shard),
                    native_factory(layout),
                    one_thread(),
                )
                .unwrap()
            })
        })
        .collect();
    // A read-only observer connection: handshakes as an explicit id
    // outside the declared worker range (ANY would work too — it is
    // assigned above the declared range), then just reads the publish
    // stream until SHUTDOWN.
    let observer = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut s = TcpStream::connect(&addr).unwrap();
            wire::write_frame(
                &mut s,
                &Frame::Hello { proto: PROTO_NT1, worker: 5 },
            )
            .unwrap();
            let mut scratch = Vec::new();
            let mut metas: Vec<(u64, PublishMeta)> = Vec::new();
            loop {
                match wire::read_frame(&mut s, &mut scratch).unwrap() {
                    Frame::Welcome { .. } => {}
                    Frame::Publish { version, meta, .. } => metas.push((version, meta)),
                    Frame::Shutdown => return metas,
                    f => panic!("unexpected frame {f:?}"),
                }
            }
        })
    };
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 1;
    cfg.max_updates = 20;
    cfg.eval_every_secs = 0.0;
    cfg.time_limit_secs = Some(60.0);
    let res = train_remote(&cfg, theta.data.clone(), net, 2, None);
    assert_eq!(res.stats.updates, 20);
    let metas = observer.join().unwrap();
    for w in workers {
        w.join().unwrap();
    }
    // Every aggregated version reports exactly the two pushing workers
    // as live (the observer never pushes, so the gate never counts it)
    // and staleness within τ.
    assert!(!metas.is_empty(), "observer saw no publishes");
    for (version, meta) in &metas {
        if *version == 0 {
            continue; // handshake snapshot of the seed θ: metadata unknown
        }
        assert_eq!(meta.live, 2, "v{version}: live count");
        assert!(
            meta.staleness <= cfg.tau,
            "v{version}: staleness {} exceeds τ",
            meta.staleness
        );
    }
}
