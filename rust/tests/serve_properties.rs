//! Property tests for the serving substrate (ISSUE 8 satellites):
//! [`BatchServer`] flush semantics, the bounded [`Stats`] quantile
//! reservoir's edges, and the version-gated monotone install contract
//! of [`PosteriorCache`] under concurrency.
//!
//! These pin behaviour the read-path replica fleet leans on: the batch
//! server's max-rows flush must short-circuit the deadline (tail
//! latency under load), the deadline must flush partial batches (tail
//! latency when idle), and the posterior cache must never publish a
//! lower version or a torn snapshot no matter how installs race.
//!
//! The ADVGPRT1 (ISSUE 9) satellites extend the file with two more
//! groups: the router's versioned [`AnswerCache`] (a hit requires the
//! exact `(posterior version, row bytes)` key; a newer version makes
//! every stale entry unreachable; the capacity bound evicts without
//! ever serving a wrong-version or wrong-row answer — driven by a
//! seeded generator over colliding-hash rows) and **cross-session
//! batching** (the latency budget is anchored at the oldest staged
//! row so stragglers cannot starve it, `max_rows` short-circuits the
//! deadline across sessions, and replies stay with their session
//! under a 4-writer interleaving race).

use advgp::gp::{SparseGp, Theta, ThetaLayout};
use advgp::linalg::Mat;
use advgp::serve::{AnswerCache, BatchConfig, BatchServer, PosteriorCache};
use advgp::util::rng::Pcg64;
use advgp::util::Stats;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A small posterior cache seeded at version 1 (mirrors the batch
/// server's own unit-test fixture).
fn seeded_cache(m: usize, d: usize) -> (Arc<PosteriorCache>, Theta) {
    let layout = ThetaLayout::new(m, d);
    let mut rng = Pcg64::seeded(77);
    let z = Mat::from_vec(m, d, (0..m * d).map(|_| rng.normal()).collect());
    let mut th = Theta::init(layout, &z);
    for v in th.mu_mut() {
        *v = rng.normal();
    }
    let cache = Arc::new(PosteriorCache::new(layout));
    cache.install(1, &th.data);
    (cache, th)
}

// ---------------------------------------------------------------- //
// BatchServer flush semantics                                       //
// ---------------------------------------------------------------- //

/// A full batch flushes immediately: with a deadline far beyond the
/// test's patience, `max_rows` staged rows must come back long before
/// that deadline could have fired.
#[test]
fn max_rows_flush_short_circuits_the_deadline() {
    let (cache, _th) = seeded_cache(4, 2);
    let cfg = BatchConfig { max_rows: 4, latency_budget: Duration::from_secs(30) };
    let (server, client) = BatchServer::start(cache, None, cfg);
    let row = [0.25, -0.5];
    let t0 = Instant::now();
    let receivers: Vec<_> =
        (0..4).map(|_| client.submit(&row).expect("server alive")).collect();
    for r in receivers {
        r.recv().expect("reply");
    }
    let waited = t0.elapsed();
    assert!(
        waited < Duration::from_secs(5),
        "full batch waited {waited:?} — the deadline was consulted instead of \
         the row count"
    );
    drop(client);
    let report = server.join();
    assert_eq!(report.rows, 4);
    assert_eq!(report.batches, 1, "exactly one full-batch flush");
}

/// A partial batch flushes at the deadline: fewer than `max_rows` rows
/// must still be answered once `latency_budget` elapses.
#[test]
fn deadline_flushes_a_partial_batch() {
    let (cache, _th) = seeded_cache(4, 2);
    let cfg = BatchConfig { max_rows: 1000, latency_budget: Duration::from_millis(30) };
    let (server, client) = BatchServer::start(cache, None, cfg);
    let row = [0.1, 0.2];
    let receivers: Vec<_> =
        (0..3).map(|_| client.submit(&row).expect("server alive")).collect();
    let t0 = Instant::now();
    for r in receivers {
        r.recv().expect("reply");
    }
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "partial batch never flushed"
    );
    drop(client);
    let report = server.join();
    assert_eq!(report.rows, 3);
    assert_eq!(report.batches, 1, "one deadline flush carrying all staged rows");
    assert_eq!(report.batch_rows.max, 3.0);
}

/// `max_rows = 1` degenerates to one blocked call per row — batching
/// off, still correct.
#[test]
fn single_row_batches_answer_every_row() {
    let (cache, _th) = seeded_cache(4, 2);
    let cfg = BatchConfig { max_rows: 1, latency_budget: Duration::ZERO };
    let (server, client) = BatchServer::start(cache, None, cfg);
    let row = [0.4, 0.4];
    for _ in 0..5 {
        client.predict(&row).expect("server alive");
    }
    drop(client);
    let report = server.join();
    assert_eq!(report.rows, 5);
    assert_eq!(report.batches, 5, "every row its own flush at max_rows=1");
    assert_eq!(report.batch_rows.max, 1.0);
}

/// No traffic, no flushes: the serve loop blocks for a first row
/// rather than spinning empty deadline flushes, and an idle server
/// reports a zeroed ledger.
#[test]
fn idle_server_flushes_nothing() {
    let (cache, _th) = seeded_cache(4, 2);
    let cfg = BatchConfig { max_rows: 8, latency_budget: Duration::from_millis(1) };
    let (server, client) = BatchServer::start(cache, None, cfg);
    std::thread::sleep(Duration::from_millis(50));
    drop(client);
    let report = server.join();
    assert_eq!((report.rows, report.batches), (0, 0), "no empty-batch flushes");
    assert_eq!(report.batch_rows.n, 0);
}

// ---------------------------------------------------------------- //
// Stats: 512-slot reservoir quantile edges                          //
// ---------------------------------------------------------------- //

/// While n ≤ the reservoir capacity every sample is retained, so
/// quantiles are exact order statistics — including n = 1 and n = 512
/// exactly at the boundary.
#[test]
fn reservoir_quantiles_are_exact_below_capacity() {
    // n = 1: every quantile is the lone sample.
    let mut s = Stats::new();
    s.push(7.5);
    for q in [0.0, 0.5, 0.999, 1.0] {
        assert_eq!(s.quantile(q), 7.5);
    }
    // n = 512 (the capacity boundary), pushed in adversarial (reversed)
    // order: still exact.
    let mut s = Stats::new();
    for x in (1..=512).rev() {
        s.push(x as f64);
    }
    assert_eq!(s.n, 512);
    assert_eq!(s.quantile(0.0), 1.0);
    assert_eq!(s.quantile(1.0), 512.0);
    // index round(511·q), 0-based over the sorted sample.
    assert_eq!(s.quantile(0.5), 257.0);
    assert_eq!(s.quantile(0.99), 507.0);
    // Welford agrees with the closed form for 1..=512.
    assert!((s.mean() - 256.5).abs() < 1e-9);
}

/// Empty stats answer NaN, not a panic.
#[test]
fn empty_reservoir_quantile_is_nan() {
    let s = Stats::new();
    assert!(s.quantile(0.5).is_nan());
}

/// Far beyond capacity (n ≫ 512) the reservoir is a uniform sample:
/// quantile estimates must stay inside the observed range, be monotone
/// in q, and land near the truth for a uniform stream — while the
/// exact min/max/mean stay exact (they bypass the reservoir).
#[test]
fn reservoir_quantiles_stay_sane_far_beyond_capacity() {
    let n = 200_000u64;
    let mut s = Stats::new();
    for i in 0..n {
        s.push(i as f64);
    }
    assert_eq!(s.n, n);
    assert_eq!(s.min, 0.0);
    assert_eq!(s.max, (n - 1) as f64);
    assert!((s.mean() - (n - 1) as f64 / 2.0).abs() < 1e-6 * n as f64);
    let qs = [0.01, 0.25, 0.5, 0.75, 0.99];
    let mut prev = f64::NEG_INFINITY;
    for &q in &qs {
        let est = s.quantile(q);
        assert!(est >= s.min && est <= s.max, "q={q}: {est} outside range");
        assert!(est >= prev, "q={q}: quantiles not monotone");
        prev = est;
        // A 512-point uniform sample pins quantiles to within a few
        // percentage points with overwhelming probability; the internal
        // RNG is fixed-seed so this is deterministic, not flaky.
        let true_q = q * (n - 1) as f64;
        assert!(
            (est - true_q).abs() < 0.08 * n as f64,
            "q={q}: estimate {est} vs truth {true_q}"
        );
    }
    // Determinism: the same push sequence reproduces the same reservoir.
    let mut s2 = Stats::new();
    for i in 0..n {
        s2.push(i as f64);
    }
    for &q in &qs {
        assert_eq!(s.quantile(q), s2.quantile(q), "fixed-seed reservoir drifted");
    }
}

// ---------------------------------------------------------------- //
// PosteriorCache: version-gated monotone installs under races       //
// ---------------------------------------------------------------- //

/// θ deterministically derived from (base, version): every coordinate
/// carries the version, so a torn snapshot (coordinates from two
/// versions) or a mislabeled one (gp built from a different version
/// than the tag) cannot go unnoticed.
fn theta_for_version(base: &Theta, v: u64) -> Vec<f64> {
    base.data.iter().map(|&x| x + v as f64 * 1e-6).collect()
}

/// Concurrent stale/fresh installs: the cache must end at the maximum
/// version, never regress at any intermediate observation, and every
/// snapshot a reader clones must be internally consistent (version tag
/// matches the θ the posterior was built from, bitwise).
#[test]
fn concurrent_installs_are_version_gated_and_untorn() {
    let (_cache, base) = seeded_cache(4, 2);
    let layout = base.layout;
    let cache = Arc::new(PosteriorCache::new(layout));
    let max_v = 24u64;
    let writers = 4u64;
    let stop = Arc::new(AtomicBool::new(false));

    // Reader: version must be non-decreasing, snapshots never torn.
    let reader = {
        let cache = Arc::clone(&cache);
        let base = base.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut last = 0u64;
            let mut observed = 0usize;
            while !stop.load(Ordering::SeqCst) {
                if let Some(p) = cache.get() {
                    assert!(
                        p.version >= last,
                        "published version regressed: {} after {last}",
                        p.version
                    );
                    last = p.version;
                    let expect = theta_for_version(&base, p.version);
                    for (i, (a, b)) in
                        expect.iter().zip(&p.gp.theta.data).enumerate()
                    {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "torn snapshot at v{}: θ[{i}]",
                            p.version
                        );
                    }
                    observed += 1;
                }
                std::thread::yield_now();
            }
            observed
        })
    };

    // Writers: interleaved stale and fresh installs.  Writer w installs
    // versions w+1, w+1+W, w+1+2W, … — so at any moment some writers
    // are behind the published version (their installs must be dropped)
    // and some are ahead.
    std::thread::scope(|scope| {
        for w in 0..writers {
            let cache = Arc::clone(&cache);
            let base = base.clone();
            scope.spawn(move || {
                let mut v = w + 1;
                while v <= max_v {
                    let accepted = cache.install(v, &theta_for_version(&base, v));
                    if accepted {
                        // An accepted install must be visible at ≥ v.
                        assert!(cache.version().unwrap() >= v);
                    }
                    v += writers;
                }
                // Re-offering old versions after the fact must be
                // refused (monotone gate, not last-writer-wins).
                assert!(!cache.install(1, &theta_for_version(&base, 1)));
            });
        }
    });
    stop.store(true, Ordering::SeqCst);
    let observed = reader.join().unwrap();
    assert!(observed > 0, "reader never saw a snapshot");
    assert_eq!(cache.version(), Some(max_v), "cache settled below the max version");
    // The surviving posterior is exactly the max version's θ.
    let final_post = cache.get().unwrap();
    let expect = theta_for_version(&base, max_v);
    for (a, b) in expect.iter().zip(&final_post.gp.theta.data) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

// ---------------------------------------------------------------- //
// AnswerCache: exact-key hits, version gating, bounded eviction     //
// (ADVGPRT1 satellite)                                              //
// ---------------------------------------------------------------- //

/// A hit requires the exact row **bytes**: one ULP of perturbation, a
/// prefix, or a permutation all miss — and 0.0 vs −0.0, equal as
/// floats, are distinct keys because the cache compares bit patterns.
#[test]
fn cache_hits_require_exact_row_bytes() {
    let cache = AnswerCache::new(16);
    let row = [0.25, -0.5];
    cache.insert(2, &row, 1.5, 0.1);
    assert_eq!(cache.get(&row), Some((2, 1.5, 0.1)));
    let bumped = [0.25, f64::from_bits((-0.5f64).to_bits() + 1)];
    assert!(cache.get(&bumped).is_none(), "one-ULP perturbation must miss");
    assert!(cache.get(&[0.25]).is_none(), "prefix row must miss");
    assert!(cache.get(&[-0.5, 0.25]).is_none(), "permuted row must miss");
    cache.insert(2, &[0.0], 10.0, 1.0);
    cache.insert(2, &[-0.0], 20.0, 2.0);
    assert_eq!(cache.get(&[0.0]), Some((2, 10.0, 1.0)));
    assert_eq!(cache.get(&[-0.0]), Some((2, 20.0, 2.0)));
}

/// Observing a newer posterior version makes every stale entry
/// unreachable at once, and a straggling insert tagged with an old
/// version is refused — the cache can only ever answer at its current
/// version.
#[test]
fn newer_posterior_version_makes_stale_answers_unreachable() {
    let cache = AnswerCache::new(16);
    cache.insert(3, &[1.0, 2.0], 0.5, 0.25);
    assert_eq!(cache.get(&[1.0, 2.0]), Some((3, 0.5, 0.25)));
    cache.advance(4); // a newer posterior was observed on this leg
    assert_eq!(cache.version(), 4);
    assert!(cache.get(&[1.0, 2.0]).is_none(), "stale answer served");
    assert!(cache.is_empty(), "stale entries must be purged, not shadowed");
    // A slow writer still holding the old version's answer: refused.
    cache.insert(3, &[1.0, 2.0], 0.5, 0.25);
    assert!(cache.get(&[1.0, 2.0]).is_none());
    // An insert carrying a newer version both advances and serves.
    cache.insert(5, &[1.0, 2.0], 0.75, 0.5);
    assert_eq!(cache.version(), 5);
    assert_eq!(cache.get(&[1.0, 2.0]), Some((5, 0.75, 0.5)));
}

/// Seeded generator over a small row alphabet with a deliberately
/// lossy 4-bucket hash, so hash collisions are the common case and
/// full-row comparison is load-bearing.  The cache may miss at any
/// time (bounded capacity evicts), but a hit must be exactly the
/// value derived from the *current* version and the *queried* row —
/// never a collision sibling's answer, never a stale version's — and
/// the capacity bound holds after every step.
#[test]
fn answer_cache_never_serves_a_wrong_version_or_wrong_row_answer() {
    fn lossy(bytes: &[u8]) -> u64 {
        bytes.iter().map(|&b| b as u64).sum::<u64>() % 4
    }
    // (mean, var) injectively derived from (version, row): the weights
    // 7^i separate every row over the {-1, 0, 1}³ alphabet, so a
    // swapped answer cannot masquerade as the right one.
    fn value_for(version: u64, row: &[f64]) -> (f64, f64) {
        let wsum: f64 =
            row.iter().enumerate().map(|(i, &x)| x * 7f64.powi(i as i32)).sum();
        (version as f64 * 1e6 + wsum, version as f64 * 1e3 - wsum)
    }
    let cap = 8;
    let cache = AnswerCache::with_hasher(cap, lossy);
    let mut rng = Pcg64::seeded(0xCA11_0B5E);
    let mut version = 1u64;
    let (mut hits, mut misses, mut bumps) = (0u64, 0u64, 0u64);
    for _ in 0..6000 {
        let row: Vec<f64> = (0..3).map(|_| rng.next_below(3) as f64 - 1.0).collect();
        match rng.next_below(12) {
            0 => {
                version += 1;
                cache.advance(version);
                bumps += 1;
                assert!(cache.is_empty(), "version bump left stale entries reachable");
            }
            1 => {
                // Straggler insert at the previous version: must be
                // dropped, not served later.
                if version > 1 {
                    let (m, v) = value_for(version - 1, &row);
                    cache.insert(version - 1, &row, m, v);
                }
            }
            2..=6 => {
                let (m, v) = value_for(version, &row);
                cache.insert(version, &row, m, v);
            }
            _ => match cache.get(&row) {
                Some((v, m, va)) => {
                    hits += 1;
                    let (em, eva) = value_for(version, &row);
                    assert_eq!(v, version, "hit at a stale version");
                    assert_eq!(m.to_bits(), em.to_bits(), "mean from another row/version");
                    assert_eq!(va.to_bits(), eva.to_bits(), "var from another row/version");
                }
                None => misses += 1, // eviction makes any miss legal
            },
        }
        assert!(cache.len() <= cap, "capacity bound violated: {}", cache.len());
    }
    assert!(
        hits > 100 && misses > 100 && bumps > 100,
        "generator must exercise every path (hits {hits}, misses {misses}, bumps {bumps})"
    );
}

// ---------------------------------------------------------------- //
// Cross-session batching (ADVGPRT1 satellite)                       //
// ---------------------------------------------------------------- //

/// The latency budget is anchored at the **oldest** staged row: a
/// straggler session dripping rows faster than the budget must not
/// keep re-arming the deadline and starve everyone else's replies.
#[test]
fn latency_budget_is_anchored_at_the_oldest_row_not_the_newest() {
    let (cache, _th) = seeded_cache(4, 2);
    let cfg = BatchConfig { max_rows: 1000, latency_budget: Duration::from_millis(100) };
    let (server, client) = BatchServer::start(cache, None, cfg);
    let stop = Arc::new(AtomicBool::new(false));
    let drip = {
        let straggler = client.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                // Replies deliberately dropped — the drip only exists
                // to keep fresh rows arriving inside every budget.
                if straggler.submit(&[0.0, 0.0]).is_none() {
                    break;
                }
                std::thread::sleep(Duration::from_millis(25));
            }
        })
    };
    let t0 = Instant::now();
    let r = client.submit(&[0.5, 0.5]).expect("server alive");
    r.recv().expect("reply");
    let waited = t0.elapsed();
    assert!(
        waited < Duration::from_secs(5),
        "budget never closed the batch under a straggler drip ({waited:?})"
    );
    stop.store(true, Ordering::SeqCst);
    drip.join().unwrap();
    drop(client);
    let report = server.join();
    assert!(report.batches >= 1);
}

/// `max_rows` short-circuits the deadline **across sessions**: four
/// sessions each staging one row against a 30 s budget are all
/// answered promptly by one fused flush.
#[test]
fn max_rows_short_circuits_the_deadline_across_sessions() {
    let (cache, _th) = seeded_cache(4, 2);
    let cfg = BatchConfig { max_rows: 4, latency_budget: Duration::from_secs(30) };
    let (server, client) = BatchServer::start(cache, None, cfg);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for s in 0..4 {
            let client = client.clone();
            scope.spawn(move || {
                let r = client.submit(&[0.1 * s as f64, 0.2]).expect("server alive");
                r.recv().expect("reply");
            });
        }
    });
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "cross-session rows did not fuse into a full batch"
    );
    drop(client);
    let report = server.join();
    assert_eq!(report.rows, 4);
    assert_eq!(report.batches, 1, "one fused flush across four sessions");
}

/// Four writers interleaving through the shared ingress queue: every
/// reply must answer its **own** row — bitwise equal to a direct
/// single-row prediction (per-row math is independent of the batch a
/// row happened to land in), so a reply swapped across sessions or
/// reordered within one cannot go unnoticed.
#[test]
fn replies_stay_with_their_session_under_four_writer_races() {
    let (cache, th) = seeded_cache(6, 3);
    let gp = SparseGp::new(th);
    let cfg = BatchConfig { max_rows: 8, latency_budget: Duration::from_millis(1) };
    let (server, client) = BatchServer::start(cache, None, cfg);
    std::thread::scope(|scope| {
        for w in 0..4u64 {
            let client = client.clone();
            let gp = &gp;
            scope.spawn(move || {
                let mut rng = Pcg64::seeded(0xD15C_0000 + w);
                for i in 0..25 {
                    let row: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
                    let p = client.predict(&row).expect("server alive");
                    let (em, ev) = gp.predict(&Mat::from_vec(1, 3, row.clone()));
                    assert_eq!(
                        p.mean.to_bits(),
                        em[0].to_bits(),
                        "writer {w} row {i}: got another row's mean"
                    );
                    assert_eq!(
                        p.var.to_bits(),
                        ev[0].to_bits(),
                        "writer {w} row {i}: got another row's var"
                    );
                    assert_eq!(p.version, 1);
                }
            });
        }
    });
    drop(client);
    let report = server.join();
    assert_eq!(report.rows, 100, "every row answered exactly once");
    assert_eq!(report.latency.n, 100);
}
