//! Property tests for the serving substrate (ISSUE 8 satellites):
//! [`BatchServer`] flush semantics, the bounded [`Stats`] quantile
//! reservoir's edges, and the version-gated monotone install contract
//! of [`PosteriorCache`] under concurrency.
//!
//! These pin behaviour the read-path replica fleet leans on: the batch
//! server's max-rows flush must short-circuit the deadline (tail
//! latency under load), the deadline must flush partial batches (tail
//! latency when idle), and the posterior cache must never publish a
//! lower version or a torn snapshot no matter how installs race.

use advgp::gp::{Theta, ThetaLayout};
use advgp::linalg::Mat;
use advgp::serve::{BatchConfig, BatchServer, PosteriorCache};
use advgp::util::rng::Pcg64;
use advgp::util::Stats;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A small posterior cache seeded at version 1 (mirrors the batch
/// server's own unit-test fixture).
fn seeded_cache(m: usize, d: usize) -> (Arc<PosteriorCache>, Theta) {
    let layout = ThetaLayout::new(m, d);
    let mut rng = Pcg64::seeded(77);
    let z = Mat::from_vec(m, d, (0..m * d).map(|_| rng.normal()).collect());
    let mut th = Theta::init(layout, &z);
    for v in th.mu_mut() {
        *v = rng.normal();
    }
    let cache = Arc::new(PosteriorCache::new(layout));
    cache.install(1, &th.data);
    (cache, th)
}

// ---------------------------------------------------------------- //
// BatchServer flush semantics                                       //
// ---------------------------------------------------------------- //

/// A full batch flushes immediately: with a deadline far beyond the
/// test's patience, `max_rows` staged rows must come back long before
/// that deadline could have fired.
#[test]
fn max_rows_flush_short_circuits_the_deadline() {
    let (cache, _th) = seeded_cache(4, 2);
    let cfg = BatchConfig { max_rows: 4, max_delay: Duration::from_secs(30) };
    let (server, client) = BatchServer::start(cache, None, cfg);
    let row = [0.25, -0.5];
    let t0 = Instant::now();
    let receivers: Vec<_> =
        (0..4).map(|_| client.submit(&row).expect("server alive")).collect();
    for r in receivers {
        r.recv().expect("reply");
    }
    let waited = t0.elapsed();
    assert!(
        waited < Duration::from_secs(5),
        "full batch waited {waited:?} — the deadline was consulted instead of \
         the row count"
    );
    drop(client);
    let report = server.join();
    assert_eq!(report.rows, 4);
    assert_eq!(report.batches, 1, "exactly one full-batch flush");
}

/// A partial batch flushes at the deadline: fewer than `max_rows` rows
/// must still be answered once `max_delay` elapses.
#[test]
fn deadline_flushes_a_partial_batch() {
    let (cache, _th) = seeded_cache(4, 2);
    let cfg = BatchConfig { max_rows: 1000, max_delay: Duration::from_millis(30) };
    let (server, client) = BatchServer::start(cache, None, cfg);
    let row = [0.1, 0.2];
    let receivers: Vec<_> =
        (0..3).map(|_| client.submit(&row).expect("server alive")).collect();
    let t0 = Instant::now();
    for r in receivers {
        r.recv().expect("reply");
    }
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "partial batch never flushed"
    );
    drop(client);
    let report = server.join();
    assert_eq!(report.rows, 3);
    assert_eq!(report.batches, 1, "one deadline flush carrying all staged rows");
    assert_eq!(report.batch_rows.max, 3.0);
}

/// `max_rows = 1` degenerates to one blocked call per row — batching
/// off, still correct.
#[test]
fn single_row_batches_answer_every_row() {
    let (cache, _th) = seeded_cache(4, 2);
    let cfg = BatchConfig { max_rows: 1, max_delay: Duration::ZERO };
    let (server, client) = BatchServer::start(cache, None, cfg);
    let row = [0.4, 0.4];
    for _ in 0..5 {
        client.predict(&row).expect("server alive");
    }
    drop(client);
    let report = server.join();
    assert_eq!(report.rows, 5);
    assert_eq!(report.batches, 5, "every row its own flush at max_rows=1");
    assert_eq!(report.batch_rows.max, 1.0);
}

/// No traffic, no flushes: the serve loop blocks for a first row
/// rather than spinning empty deadline flushes, and an idle server
/// reports a zeroed ledger.
#[test]
fn idle_server_flushes_nothing() {
    let (cache, _th) = seeded_cache(4, 2);
    let cfg = BatchConfig { max_rows: 8, max_delay: Duration::from_millis(1) };
    let (server, client) = BatchServer::start(cache, None, cfg);
    std::thread::sleep(Duration::from_millis(50));
    drop(client);
    let report = server.join();
    assert_eq!((report.rows, report.batches), (0, 0), "no empty-batch flushes");
    assert_eq!(report.batch_rows.n, 0);
}

// ---------------------------------------------------------------- //
// Stats: 512-slot reservoir quantile edges                          //
// ---------------------------------------------------------------- //

/// While n ≤ the reservoir capacity every sample is retained, so
/// quantiles are exact order statistics — including n = 1 and n = 512
/// exactly at the boundary.
#[test]
fn reservoir_quantiles_are_exact_below_capacity() {
    // n = 1: every quantile is the lone sample.
    let mut s = Stats::new();
    s.push(7.5);
    for q in [0.0, 0.5, 0.999, 1.0] {
        assert_eq!(s.quantile(q), 7.5);
    }
    // n = 512 (the capacity boundary), pushed in adversarial (reversed)
    // order: still exact.
    let mut s = Stats::new();
    for x in (1..=512).rev() {
        s.push(x as f64);
    }
    assert_eq!(s.n, 512);
    assert_eq!(s.quantile(0.0), 1.0);
    assert_eq!(s.quantile(1.0), 512.0);
    // index round(511·q), 0-based over the sorted sample.
    assert_eq!(s.quantile(0.5), 257.0);
    assert_eq!(s.quantile(0.99), 507.0);
    // Welford agrees with the closed form for 1..=512.
    assert!((s.mean() - 256.5).abs() < 1e-9);
}

/// Empty stats answer NaN, not a panic.
#[test]
fn empty_reservoir_quantile_is_nan() {
    let s = Stats::new();
    assert!(s.quantile(0.5).is_nan());
}

/// Far beyond capacity (n ≫ 512) the reservoir is a uniform sample:
/// quantile estimates must stay inside the observed range, be monotone
/// in q, and land near the truth for a uniform stream — while the
/// exact min/max/mean stay exact (they bypass the reservoir).
#[test]
fn reservoir_quantiles_stay_sane_far_beyond_capacity() {
    let n = 200_000u64;
    let mut s = Stats::new();
    for i in 0..n {
        s.push(i as f64);
    }
    assert_eq!(s.n, n);
    assert_eq!(s.min, 0.0);
    assert_eq!(s.max, (n - 1) as f64);
    assert!((s.mean() - (n - 1) as f64 / 2.0).abs() < 1e-6 * n as f64);
    let qs = [0.01, 0.25, 0.5, 0.75, 0.99];
    let mut prev = f64::NEG_INFINITY;
    for &q in &qs {
        let est = s.quantile(q);
        assert!(est >= s.min && est <= s.max, "q={q}: {est} outside range");
        assert!(est >= prev, "q={q}: quantiles not monotone");
        prev = est;
        // A 512-point uniform sample pins quantiles to within a few
        // percentage points with overwhelming probability; the internal
        // RNG is fixed-seed so this is deterministic, not flaky.
        let true_q = q * (n - 1) as f64;
        assert!(
            (est - true_q).abs() < 0.08 * n as f64,
            "q={q}: estimate {est} vs truth {true_q}"
        );
    }
    // Determinism: the same push sequence reproduces the same reservoir.
    let mut s2 = Stats::new();
    for i in 0..n {
        s2.push(i as f64);
    }
    for &q in &qs {
        assert_eq!(s.quantile(q), s2.quantile(q), "fixed-seed reservoir drifted");
    }
}

// ---------------------------------------------------------------- //
// PosteriorCache: version-gated monotone installs under races       //
// ---------------------------------------------------------------- //

/// θ deterministically derived from (base, version): every coordinate
/// carries the version, so a torn snapshot (coordinates from two
/// versions) or a mislabeled one (gp built from a different version
/// than the tag) cannot go unnoticed.
fn theta_for_version(base: &Theta, v: u64) -> Vec<f64> {
    base.data.iter().map(|&x| x + v as f64 * 1e-6).collect()
}

/// Concurrent stale/fresh installs: the cache must end at the maximum
/// version, never regress at any intermediate observation, and every
/// snapshot a reader clones must be internally consistent (version tag
/// matches the θ the posterior was built from, bitwise).
#[test]
fn concurrent_installs_are_version_gated_and_untorn() {
    let (_cache, base) = seeded_cache(4, 2);
    let layout = base.layout;
    let cache = Arc::new(PosteriorCache::new(layout));
    let max_v = 24u64;
    let writers = 4u64;
    let stop = Arc::new(AtomicBool::new(false));

    // Reader: version must be non-decreasing, snapshots never torn.
    let reader = {
        let cache = Arc::clone(&cache);
        let base = base.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut last = 0u64;
            let mut observed = 0usize;
            while !stop.load(Ordering::SeqCst) {
                if let Some(p) = cache.get() {
                    assert!(
                        p.version >= last,
                        "published version regressed: {} after {last}",
                        p.version
                    );
                    last = p.version;
                    let expect = theta_for_version(&base, p.version);
                    for (i, (a, b)) in
                        expect.iter().zip(&p.gp.theta.data).enumerate()
                    {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "torn snapshot at v{}: θ[{i}]",
                            p.version
                        );
                    }
                    observed += 1;
                }
                std::thread::yield_now();
            }
            observed
        })
    };

    // Writers: interleaved stale and fresh installs.  Writer w installs
    // versions w+1, w+1+W, w+1+2W, … — so at any moment some writers
    // are behind the published version (their installs must be dropped)
    // and some are ahead.
    std::thread::scope(|scope| {
        for w in 0..writers {
            let cache = Arc::clone(&cache);
            let base = base.clone();
            scope.spawn(move || {
                let mut v = w + 1;
                while v <= max_v {
                    let accepted = cache.install(v, &theta_for_version(&base, v));
                    if accepted {
                        // An accepted install must be visible at ≥ v.
                        assert!(cache.version().unwrap() >= v);
                    }
                    v += writers;
                }
                // Re-offering old versions after the fact must be
                // refused (monotone gate, not last-writer-wins).
                assert!(!cache.install(1, &theta_for_version(&base, 1)));
            });
        }
    });
    stop.store(true, Ordering::SeqCst);
    let observed = reader.join().unwrap();
    assert!(observed > 0, "reader never saw a snapshot");
    assert_eq!(cache.version(), Some(max_v), "cache settled below the max version");
    // The surviving posterior is exactly the max version's θ.
    let final_post = cache.get().unwrap();
    let expect = theta_for_version(&base, max_v);
    for (a, b) in expect.iter().zip(&final_post.gp.theta.data) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
