//! Integration: asynchronous parameter-server training end-to-end with
//! the native engine — convergence, staleness invariants, stragglers,
//! crash/restart, wall-clock limits.

use advgp::data::{kmeans, synth, Dataset, Standardizer};
use advgp::gp::{SparseGp, Theta, ThetaLayout};
use advgp::grad::native_factory;
use advgp::ps::coordinator::{native_eval_factory, train, TrainConfig};
use advgp::ps::worker::WorkerProfile;
use advgp::util::rng::Pcg64;
use advgp::util::rmse;
use std::time::Duration;

/// Standardized friedman problem + kmeans-initialized θ.
fn setup(n: usize, m: usize, seed: u64) -> (Dataset, Dataset, Theta, ThetaLayout) {
    let mut ds = synth::friedman(n + 200, 4, 0.4, seed);
    let mut rng = Pcg64::seeded(seed);
    ds.shuffle(&mut rng);
    let (mut train_ds, mut test_ds) = ds.split(200);
    let st = Standardizer::fit(&train_ds);
    st.apply(&mut train_ds);
    st.apply(&mut test_ds);
    let layout = ThetaLayout::new(m, 4);
    let z = kmeans::kmeans(&train_ds.x, m, 15, &mut rng);
    let theta = Theta::init(layout, &z);
    (train_ds, test_ds, theta, layout)
}

fn mean_rmse(test: &Dataset) -> f64 {
    // Targets are standardized: the mean predictor is ~0.
    rmse(&vec![0.0; test.n()], &test.y)
}

#[test]
fn async_training_beats_mean_and_reduces_neg_elbo() {
    let (train_ds, test_ds, theta, layout) = setup(2000, 16, 1);
    let elbo_probe = train_ds.head(512);
    let gp0 = SparseGp::new(theta.clone());
    let neg_elbo_0 = gp0.neg_elbo(&train_ds.x, &train_ds.y);

    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 8;
    cfg.max_updates = 300;
    cfg.eval_every_secs = 0.05;
    let shards = train_ds.shard(4);
    let res = train(
        &cfg,
        theta.data.clone(),
        shards,
        native_factory(layout),
        Some(native_eval_factory(layout, test_ds.clone(), Some(elbo_probe))),
    );
    assert_eq!(res.stats.updates, 300);
    let gp = SparseGp::new(Theta { layout, data: res.theta.clone() });
    let (mean, _) = gp.predict(&test_ds.x);
    let final_rmse = rmse(&mean, &test_ds.y);
    let baseline = mean_rmse(&test_ds);
    assert!(
        final_rmse < 0.6 * baseline,
        "rmse {final_rmse} vs mean predictor {baseline}"
    );
    let neg_elbo_t = gp.neg_elbo(&train_ds.x, &train_ds.y);
    assert!(neg_elbo_t < neg_elbo_0, "{neg_elbo_t} !< {neg_elbo_0}");
    // Trace recorded and improves over time.
    assert!(res.trace.len() >= 3);
    let first = res.trace.first().unwrap().rmse;
    let last = res.trace.last().unwrap().rmse;
    assert!(last < first, "trace should improve: {first} -> {last}");
}

#[test]
fn staleness_never_exceeds_tau() {
    for tau in [0u64, 3, 16] {
        let (train_ds, _test, theta, layout) = setup(600, 8, 2 + tau);
        let mut cfg = TrainConfig::new(layout);
        cfg.tau = tau;
        cfg.max_updates = 120;
        cfg.eval_every_secs = 0.0;
        // Heterogeneous workers to provoke staleness.
        cfg.profiles = vec![
            WorkerProfile::default(),
            WorkerProfile { straggle: Duration::from_millis(3), ..Default::default() },
            WorkerProfile { straggle: Duration::from_millis(7), ..Default::default() },
        ];
        let res = train(
            &cfg,
            theta.data.clone(),
            train_ds.shard(3),
            native_factory(layout),
            None,
        );
        // The gate guarantees staleness ≤ τ at every update.
        assert!(
            res.stats.staleness.max <= tau as f64,
            "tau={tau}: observed staleness {}",
            res.stats.staleness.max
        );
        if tau == 0 {
            // Bulk-synchronous: staleness identically zero.
            assert_eq!(res.stats.staleness.max, 0.0);
        }
    }
}

#[test]
fn diag_u_stays_positive_throughout() {
    let (train_ds, test_ds, theta, layout) = setup(800, 10, 5);
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 4;
    cfg.max_updates = 150;
    cfg.eval_every_secs = 0.02;
    let res = train(
        &cfg,
        theta.data.clone(),
        train_ds.shard(3),
        native_factory(layout),
        Some(native_eval_factory(layout, test_ds, None)),
    );
    let th = Theta { layout, data: res.theta };
    let u = th.u_mat();
    for i in 0..layout.m {
        assert!(u[(i, i)] > 0.0, "U[{i},{i}] = {}", u[(i, i)]);
    }
    // MNLP finite at every snapshot (Σ stayed SPD the whole run).
    for row in &res.trace {
        assert!(row.mnlp.is_finite());
    }
}

#[test]
fn crash_and_restart_worker_recovers() {
    let (train_ds, test_ds, theta, layout) = setup(1200, 12, 7);
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 50; // generous: survive the dark period
    cfg.max_updates = 200;
    cfg.eval_every_secs = 0.0;
    cfg.profiles = vec![
        WorkerProfile::default(),
        WorkerProfile {
            crash_at: Some(5),
            restart_after: Duration::from_millis(150),
            ..Default::default()
        },
        WorkerProfile::default(),
    ];
    let res = train(
        &cfg,
        theta.data.clone(),
        train_ds.shard(3),
        native_factory(layout),
        None,
    );
    assert_eq!(res.stats.updates, 200, "run must complete despite the crash");
    let gp = SparseGp::new(Theta { layout, data: res.theta });
    let (mean, _) = gp.predict(&test_ds.x);
    assert!(rmse(&mean, &test_ds.y) < 0.8 * mean_rmse(&test_ds));
}

/// ISSUE 3: a worker killed mid-run (permanent departure, unlike the
/// crash/restart above) must not stall the bounded-staleness gate — the
/// server retires its clock, keeps aggregating the survivors, and the
/// run still converges.  Pre-elasticity this deadlocked: the departed
/// worker's frozen clock eventually failed `min_k t_k ≥ t − τ` forever.
#[test]
fn killed_worker_retires_and_run_converges() {
    let (train_ds, test_ds, theta, layout) = setup(1200, 12, 8);
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 2; // tight gate: a frozen clock would stall within 3 updates
    cfg.max_updates = 200;
    cfg.eval_every_secs = 0.0;
    cfg.profiles = vec![
        WorkerProfile::default(),
        WorkerProfile { leave_at: Some(5), ..Default::default() },
        WorkerProfile::default(),
    ];
    let res = train(
        &cfg,
        theta.data.clone(),
        train_ds.shard(3),
        native_factory(layout),
        None,
    );
    assert_eq!(res.stats.updates, 200, "run must complete despite the kill");
    assert!(res.stats.leaves >= 1, "departure must be observed");
    // Staleness stays bounded by τ for the *live* membership throughout.
    assert!(res.stats.staleness.max <= cfg.tau as f64);
    let gp = SparseGp::new(Theta { layout, data: res.theta });
    let (mean, _) = gp.predict(&test_ds.x);
    assert!(rmse(&mean, &test_ds.y) < 0.8 * mean_rmse(&test_ds));
}

#[test]
fn time_limit_stops_run() {
    let (train_ds, _test, theta, layout) = setup(1500, 12, 9);
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 8;
    cfg.max_updates = u64::MAX / 2; // effectively unbounded
    cfg.eval_every_secs = 0.0;
    cfg.time_limit_secs = Some(0.5);
    let start = std::time::Instant::now();
    let res = train(
        &cfg,
        theta.data.clone(),
        train_ds.shard(2),
        native_factory(layout),
        None,
    );
    assert!(start.elapsed() < Duration::from_secs(10));
    assert!(res.stats.updates > 0, "should do some updates before the limit");
}

/// ISSUE 2 satellite: a `max_rows`-capped worker must rotate through
/// its *whole* shard over successive iterations (the old code resampled
/// the same `head(max_rows)` rows forever).  A probe engine records the
/// row ids (encoded in the first feature) that reach the gradient.
#[test]
fn capped_worker_covers_whole_shard() {
    use advgp::grad::{GradEngine, GradResult};
    use advgp::linalg::Mat;
    use std::collections::HashSet;
    use std::sync::{Arc, Mutex};

    struct Probe {
        layout: ThetaLayout,
        cap: usize,
        seen: Arc<Mutex<HashSet<i64>>>,
    }
    impl GradEngine for Probe {
        fn layout(&self) -> ThetaLayout {
            self.layout
        }
        fn grad(&mut self, _theta: &[f64], x: &Mat, _y: &[f64]) -> GradResult {
            assert_eq!(x.rows, self.cap, "window must be exactly the cap");
            let mut seen = self.seen.lock().unwrap();
            for i in 0..x.rows {
                seen.insert(x.row(i)[0].round() as i64);
            }
            GradResult { value: 0.0, grad: vec![0.0; self.layout.len()] }
        }
        fn name(&self) -> &'static str {
            "probe"
        }
    }

    let n = 30usize;
    let cap = 8usize;
    let layout = ThetaLayout::new(2, 1);
    let shard = Dataset {
        x: Mat::from_vec(n, 1, (0..n).map(|i| i as f64).collect()),
        y: vec![0.0; n],
    };
    let z0 = Mat::from_vec(2, 1, vec![3.0, 20.0]);
    let theta = Theta::init(layout, &z0);
    let seen = Arc::new(Mutex::new(HashSet::new()));
    let seen_f = Arc::clone(&seen);
    let factory: advgp::grad::EngineFactory = Arc::new(move |_worker| {
        Box::new(Probe { layout, cap, seen: Arc::clone(&seen_f) })
    });
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 32;
    cfg.max_updates = 12; // ≥ ⌈30/8⌉ = 4 worker iterations needed
    cfg.eval_every_secs = 0.0;
    cfg.profiles = vec![WorkerProfile { max_rows: cap, ..Default::default() }];
    train(&cfg, theta.data.clone(), vec![shard], factory, None);
    let seen = seen.lock().unwrap();
    let missing: Vec<usize> = (0..n).filter(|i| !seen.contains(&(*i as i64))).collect();
    assert!(
        missing.is_empty(),
        "capped worker never saw rows {missing:?} (saw {} of {n})",
        seen.len()
    );
}

#[test]
fn sync_tau0_matches_single_worker_semantics() {
    // With τ=0 and identical data splits, every update aggregates one
    // fresh gradient per worker computed at the same version — the sum
    // equals the full-batch gradient, so 2-worker sync must equal
    // 1-worker sync trajectory exactly (deterministic engines).
    let (train_ds, _test, theta, layout) = setup(400, 6, 11);
    let run = |shards: Vec<Dataset>| {
        let mut cfg = TrainConfig::new(layout);
        cfg.tau = 0;
        cfg.max_updates = 25;
        cfg.eval_every_secs = 0.0;
        train(&cfg, theta.data.clone(), shards, native_factory(layout), None)
    };
    let r1 = run(vec![train_ds.clone()]);
    let r2 = run(train_ds.shard(3));
    for (a, b) in r1.theta.iter().zip(&r2.theta) {
        assert!((a - b).abs() < 1e-9, "sync trajectories diverged: {a} vs {b}");
    }
}
