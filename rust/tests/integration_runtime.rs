//! Integration: the PJRT (JAX/Pallas artifact) engine and the pure-Rust
//! native engine must agree — value, every gradient block, predictions,
//! and ELBO terms.  This pins L1+L2 against L3's independent math.
//!
//! Requires `make artifacts` (skips gracefully if absent).

use advgp::data::synth;
use advgp::gp::{SparseGp, Theta, ThetaLayout};
use advgp::grad::{native::NativeEngine, GradEngine};
use advgp::linalg::Mat;
use advgp::runtime::{Manifest, PosteriorEval, XlaEngine, XlaEvaluator};
use advgp::util::rng::Pcg64;
use std::path::Path;

fn manifest() -> Option<Manifest> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Ok(m) if m.find(advgp::runtime::ArtifactKind::Grad, 16, 4).is_ok() => Some(m),
        _ => {
            eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
            None
        }
    }
}

fn test_theta(layout: ThetaLayout, seed: u64) -> Theta {
    let mut rng = Pcg64::seeded(seed);
    let z = Mat::from_vec(
        layout.m,
        layout.d,
        (0..layout.m * layout.d).map(|_| rng.normal() * 0.7).collect(),
    );
    let mut th = Theta::init(layout, &z);
    for v in th.mu_mut() {
        *v = rng.normal() * 0.2;
    }
    let m = layout.m;
    let mut u = Mat::eye(m);
    for i in 0..m {
        u[(i, i)] = 0.8 + 0.2 * rng.next_f64();
        for j in i + 1..m {
            u[(i, j)] = rng.normal() * 0.03;
        }
    }
    th.set_u_mat(&u);
    th.data[layout.log_a0_idx()] = 0.1;
    th.data[layout.log_sigma_idx()] = -0.2;
    th
}

#[test]
fn xla_and_native_gradients_agree() {
    let Some(man) = manifest() else { return };
    let layout = ThetaLayout::new(16, 4);
    let th = test_theta(layout, 1);
    // 1500 rows: exercises full blocks AND the padded tail (b=1024).
    let ds = synth::friedman(1500, 4, 0.4, 2);
    let mut xla = XlaEngine::from_manifest(&man, 16, 4).unwrap();
    let mut nat = NativeEngine::new(layout);
    let rx = xla.grad(&th.data, &ds.x, &ds.y);
    let rn = nat.grad(&th.data, &ds.x, &ds.y);
    let rel = (rx.value - rn.value).abs() / rn.value.abs().max(1.0);
    assert!(rel < 5e-4, "value: xla {} vs native {}", rx.value, rn.value);
    let mut worst = (0usize, 0.0f64);
    for i in 0..layout.len() {
        let denom = rn.grad[i].abs().max(rx.grad[i].abs()).max(1e-2);
        let rel = (rx.grad[i] - rn.grad[i]).abs() / denom;
        if rel > worst.1 {
            worst = (i, rel);
        }
    }
    assert!(
        worst.1 < 5e-3,
        "grad coord {}: xla {} vs native {} (rel {:.2e})",
        worst.0, rx.grad[worst.0], rn.grad[worst.0], worst.1
    );
}

#[test]
fn xla_predictions_match_native_sparse_gp() {
    let Some(man) = manifest() else { return };
    let layout = ThetaLayout::new(16, 4);
    let th = test_theta(layout, 3);
    let ds = synth::friedman(700, 4, 0.3, 4);
    let eval = XlaEvaluator::from_manifest(&man, 16, 4).unwrap();
    let (mx, vx) = eval.predict(&th.data, &ds.x).unwrap();
    let gp = SparseGp::new(th.clone());
    let (mn, vn) = gp.predict(&ds.x);
    assert_eq!(mx.len(), 700);
    for i in 0..700 {
        assert!((mx[i] - mn[i]).abs() < 5e-4 * (1.0 + mn[i].abs()), "mean {i}");
        assert!((vx[i] - vn[i]).abs() < 5e-3 * (1.0 + vn[i].abs()), "var {i}");
    }
}

#[test]
fn xla_elbo_term_matches_native() {
    let Some(man) = manifest() else { return };
    let layout = ThetaLayout::new(16, 4);
    let th = test_theta(layout, 5);
    let ds = synth::friedman(3000, 4, 0.3, 6);
    let eval = XlaEvaluator::from_manifest(&man, 16, 4).unwrap();
    let (g, sse) = eval.elbo_data_term(&th.data, &ds.x, &ds.y).unwrap();
    let gp = SparseGp::new(th.clone());
    let want_g = gp.data_term(&ds.x, &ds.y);
    let (mean, _) = gp.predict(&ds.x);
    let want_sse: f64 = mean
        .iter()
        .zip(&ds.y)
        .map(|(p, t)| (p - t) * (p - t))
        .sum();
    assert!((g - want_g).abs() / want_g.abs() < 1e-3, "{g} vs {want_g}");
    assert!((sse - want_sse).abs() / want_sse.abs() < 1e-3, "{sse} vs {want_sse}");
}

#[test]
fn mask_padding_contributes_zero() {
    let Some(man) = manifest() else { return };
    let layout = ThetaLayout::new(16, 4);
    let th = test_theta(layout, 7);
    // 1024 rows == exactly one block vs the same rows + pathological tail
    // values that the mask must cancel: compare against 1024+1 rows where
    // the extra row is processed in a second padded block.
    let ds = synth::friedman(1024, 4, 0.3, 8);
    let mut one_more = synth::friedman(1025, 4, 0.3, 8);
    // Make row 1024 contribute a known amount: run it separately.
    let extra_x = Mat::from_vec(1, 4, one_more.x.data[1024 * 4..].to_vec());
    let extra_y = vec![one_more.y[1024]];
    one_more.x.data.truncate(1025 * 4);
    let mut xla = XlaEngine::from_manifest(&man, 16, 4).unwrap();
    let full = xla.grad(&th.data, &one_more.x, &one_more.y);
    let base = xla.grad(&th.data, &ds.x, &ds.y);
    let extra = xla.grad(&th.data, &extra_x, &extra_y);
    assert!(
        (full.value - base.value - extra.value).abs() < 1e-3,
        "{} vs {} + {}",
        full.value, base.value, extra.value
    );
}
