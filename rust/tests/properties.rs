//! Property tests (via the in-repo `testing` harness) over the system's
//! core invariants:
//!
//! * prox (eqs. 18–20): positivity, hyper-invariance, KL contraction
//! * feature maps: K_nn − ΦΦᵀ PSD for every map in §5
//! * delay gate: staleness bound never violated under random schedules
//! * linalg: factorization round-trips
//! * native gradient: −∇G is always a descent direction
//! * data sharding: partition + balance
//! * KL nonnegativity, RNG stream independence

use advgp::data::synth;
use advgp::gp::featuremap::{EnsembleNystrom, FeatureMap, InducingChol, Nystrom, Rvm};
use advgp::gp::{Theta, ThetaLayout};
use advgp::kernel::ArdParams;
use advgp::linalg::{cholesky_lower, spd_inverse, sym_eig, Mat};
use advgp::opt::prox_update;
use advgp::ps::DelayGate;
use advgp::testing::{forall, gens, Config};
use advgp::util::rng::Pcg64;

fn cfg() -> Config {
    Config::default()
}

fn rand_mat(rng: &mut Pcg64, r: usize, c: usize, scale: f64) -> Mat {
    Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal() * scale).collect())
}

#[test]
fn prox_diag_positive_and_shrinks_kl() {
    forall(
        "prox positivity + KL contraction",
        &cfg(),
        |rng: &mut Pcg64| {
            let m = 2 + rng.next_below(6) as usize;
            let d = 1 + rng.next_below(4) as usize;
            let layout = ThetaLayout::new(m, d);
            let theta: Vec<f64> =
                (0..layout.len()).map(|_| rng.normal() * 5.0).collect();
            let gamma = rng.uniform(1e-4, 2.0);
            (layout, theta, gamma)
        },
        |(layout, theta, gamma)| {
            let mut th = theta.clone();
            prox_update(layout, &mut th, *gamma);
            for i in 0..layout.len() {
                if layout.is_u_diag(i) {
                    advgp::prop_assert!(th[i] > 0.0, "diag {i} = {}", th[i]);
                }
                if !layout.is_variational(i) {
                    advgp::prop_assert!(th[i] == theta[i], "hyper {i} moved");
                }
            }
            let mk = |data: &[f64]| Theta { layout: *layout, data: data.to_vec() }.kl();
            advgp::prop_assert!(
                mk(&th) <= mk(theta) + 1e-9,
                "KL grew: {} -> {}",
                mk(theta),
                mk(&th)
            );
            Ok(())
        },
    );
}

#[test]
fn feature_maps_keep_residual_psd() {
    forall(
        "K_nn − ΦΦᵀ ⪰ 0 for all §5 maps",
        &Config { cases: 24, ..cfg() },
        |rng: &mut Pcg64| {
            let d = 1 + rng.next_below(4) as usize;
            let m = 2 + rng.next_below(8) as usize;
            let b = 8 + rng.next_below(16) as usize;
            let params = ArdParams {
                log_a0: rng.uniform(-0.5, 0.5),
                log_eta: (0..d).map(|_| rng.uniform(-0.5, 0.5)).collect(),
            };
            let z = rand_mat(rng, m, d, 1.0);
            let z2 = rand_mat(rng, m.max(2), d, 1.0);
            let x = rand_mat(rng, b, d, 1.0);
            let alpha: Vec<f64> = (0..m).map(|_| rng.uniform(0.0, 10.0)).collect();
            (params, z, z2, x, alpha)
        },
        |(params, z, z2, x, alpha)| {
            let maps: Vec<Box<dyn FeatureMap>> = vec![
                Box::new(InducingChol::build(params, z.clone())),
                Box::new(Nystrom::build(params, z.clone())),
                Box::new(EnsembleNystrom::build(
                    params,
                    vec![z.clone(), z2.clone()],
                )),
                Box::new(Rvm::build(params, z.clone(), alpha)),
            ];
            let knn = advgp::kernel::cross(params, x, x);
            for (i, map) in maps.iter().enumerate() {
                let pb = map.phi(params, x);
                let ppt = pb.phi.matmul(&pb.phi.transpose());
                let mut resid = knn.clone();
                resid.axpy(-1.0, &ppt);
                let (w, _) = sym_eig(&resid);
                let min = w.iter().cloned().fold(f64::INFINITY, f64::min);
                advgp::prop_assert!(
                    min > -1e-6 * params.a0_sq(),
                    "map {i}: min eig {min}"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn delay_gate_staleness_bounded_under_random_schedules() {
    forall(
        "gate invariant",
        &Config { cases: 200, ..cfg() },
        |rng: &mut Pcg64| {
            let workers = 1 + rng.next_below(6) as usize;
            let tau = rng.next_below(20);
            let events: Vec<(usize, u64)> = (0..100)
                .map(|_| (rng.next_below(workers as u64) as usize, rng.next_below(3)))
                .collect();
            (workers, tau, events)
        },
        |(workers, tau, events)| {
            let mut gate = DelayGate::new(*workers, *tau);
            let mut t: u64 = 0;
            let mut last_pull = vec![0u64; *workers];
            for (w, lag) in events {
                let v = last_pull[*w].saturating_sub(*lag).min(t);
                gate.record(*w, v);
                while gate.permits(t) {
                    if let Some(s) = gate.staleness(t) {
                        advgp::prop_assert!(
                            s <= *tau,
                            "staleness {s} > tau {tau} at t={t}"
                        );
                    }
                    t += 1;
                    last_pull[*w] = t;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn spd_roundtrips() {
    forall(
        "cholesky/inverse round-trips",
        &Config { cases: 40, ..cfg() },
        |rng: &mut Pcg64| {
            let n = 1 + rng.next_below(20) as usize;
            let a = rand_mat(rng, n, n, 1.0);
            let mut s = a.transpose().matmul(&a);
            for i in 0..n {
                s[(i, i)] += 0.5 + n as f64 * 0.05;
            }
            s
        },
        |s| {
            let n = s.rows;
            let l = cholesky_lower(s).map_err(|e| e.to_string())?;
            let back = l.matmul(&l.transpose());
            advgp::prop_assert!(
                back.max_abs_diff(s) < 1e-8 * (1.0 + s.frob_norm()),
                "LLᵀ ≠ A"
            );
            let inv = spd_inverse(s).map_err(|e| e.to_string())?;
            let prod = s.matmul(&inv);
            advgp::prop_assert!(
                prod.max_abs_diff(&Mat::eye(n)) < 1e-7 * (1.0 + s.frob_norm()),
                "A·A⁻¹ ≠ I"
            );
            Ok(())
        },
    );
}

#[test]
fn native_gradient_is_descent_direction() {
    forall(
        "−∇G is a descent direction",
        &Config { cases: 20, ..cfg() },
        |rng: &mut Pcg64| {
            let m = 3 + rng.next_below(5) as usize;
            let d = 2 + rng.next_below(3) as usize;
            let seed = rng.next_u64();
            (m, d, seed)
        },
        |(m, d, seed)| {
            use advgp::grad::{native::NativeEngine, GradEngine};
            let layout = ThetaLayout::new(*m, *d);
            let mut rng = Pcg64::seeded(*seed);
            let z = rand_mat(&mut rng, *m, *d, 0.8);
            let mut th = Theta::init(layout, &z);
            for v in th.mu_mut() {
                *v = rng.normal() * 0.3;
            }
            let ds = synth::gp_draw(24, *d, 0.3, *seed);
            let mut eng = NativeEngine::new(layout);
            let r = eng.grad(&th.data, &ds.x, &ds.y);
            let gnorm: f64 = r.grad.iter().map(|g| g * g).sum::<f64>().sqrt();
            if gnorm < 1e-10 {
                return Ok(());
            }
            let step = 1e-6 / gnorm;
            let moved: Vec<f64> = th
                .data
                .iter()
                .zip(&r.grad)
                .map(|(t, g)| t - step * g)
                .collect();
            let r2 = eng.grad(&moved, &ds.x, &ds.y);
            advgp::prop_assert!(
                r2.value <= r.value + 1e-9 * r.value.abs(),
                "uphill: {} -> {}",
                r.value,
                r2.value
            );
            Ok(())
        },
    );
}

#[test]
fn dataset_shard_partition_properties() {
    forall(
        "shard partitioning",
        &Config { cases: 60, ..cfg() },
        |rng: &mut Pcg64| {
            let n = 1 + rng.next_below(500) as usize;
            let r = 1 + rng.next_below(16) as usize;
            let seed = rng.next_u64();
            (n, r.min(n), seed)
        },
        |(n, r, seed)| {
            let ds = synth::friedman((*n).max(4), 4, 0.1, *seed);
            let ds = ds.head(*n);
            let shards = ds.shard(*r);
            advgp::prop_assert!(shards.len() == *r, "shard count");
            let total: usize = shards.iter().map(|s| s.n()).sum();
            advgp::prop_assert!(total == ds.n(), "rows lost: {total} != {}", ds.n());
            let sizes: Vec<usize> = shards.iter().map(|s| s.n()).collect();
            let (mn, mx) = (
                *sizes.iter().min().unwrap(),
                *sizes.iter().max().unwrap(),
            );
            advgp::prop_assert!(mx - mn <= 1, "imbalance {sizes:?}");
            Ok(())
        },
    );
}

#[test]
fn rng_streams_do_not_collide() {
    forall(
        "independent streams",
        &Config { cases: 30, ..cfg() },
        gens::usize_in(0, 10_000),
        |&seed| {
            let mut a = Pcg64::new(seed as u64, 1);
            let mut b = Pcg64::new(seed as u64, 2);
            let xa: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
            let xb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
            advgp::prop_assert!(xa != xb, "streams collided for seed {seed}");
            Ok(())
        },
    );
}

#[test]
fn kl_nonnegative_for_valid_u() {
    forall(
        "KL(q||p) >= 0",
        &Config { cases: 80, ..cfg() },
        |rng: &mut Pcg64| {
            let m = 1 + rng.next_below(10) as usize;
            let layout = ThetaLayout::new(m, 1);
            let z = Mat::zeros(m, 1);
            let mut th = Theta::init(layout, &z);
            for v in th.mu_mut() {
                *v = rng.normal() * 2.0;
            }
            let mut u = Mat::zeros(m, m);
            for i in 0..m {
                u[(i, i)] = rng.uniform(0.05, 3.0);
                for j in i + 1..m {
                    u[(i, j)] = rng.normal() * 0.3;
                }
            }
            th.set_u_mat(&u);
            th
        },
        |th| {
            advgp::prop_assert!(th.kl() >= -1e-9, "KL = {}", th.kl());
            Ok(())
        },
    );
}
