//! Serial/parallel equivalence of the pool-dispatched linalg + kernel
//! hot paths (ISSUE 1 satellite).
//!
//! Strategy: force pool dispatch for *every* op (`set_par_min_flops(1)`)
//! and compare against the inline path (`pool::with_budget(1, …)`)
//! across odd shapes — 0/1 rows, sizes that are not multiples of any
//! block size — and thread budgets 1–8.  The linalg family must match
//! **bitwise** (each output row keeps its serial accumulation order);
//! the gradient engine, whose lane reduction reorders chunk sums, must
//! match to tight floating-point tolerance.

use advgp::gp::featuremap::{FeatureMap, InducingChol, PhiBatch, PhiWorkspace};
use advgp::gp::{PredictWorkspace, SparseGp, Theta, ThetaLayout};
use advgp::linalg::dot;
use advgp::grad::{native::NativeEngine, GradEngine};
use advgp::kernel::{cross, cross_pairwise, ArdParams};
use advgp::linalg::{set_par_min_flops, Mat};
use advgp::testing::{forall, Config};
use advgp::util::pool;
use advgp::util::rng::Pcg64;

const BUDGETS: [usize; 4] = [2, 3, 4, 8];

fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> Mat {
    Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
}

/// Odd shapes around block boundaries (block sizes are derived from the
/// thread budget, so cover 0, 1, primes and non-multiples of 4/8).
fn dims() -> impl advgp::testing::Gen<(usize, usize, usize)> {
    |rng: &mut Pcg64| {
        let pick = |rng: &mut Pcg64| {
            const SIZES: [usize; 9] = [0, 1, 2, 3, 5, 7, 13, 33, 65];
            SIZES[rng.next_below(SIZES.len() as u64) as usize]
        };
        (pick(rng), pick(rng).max(1), pick(rng).max(1))
    }
}

#[test]
fn matmul_family_bitwise_identical_across_budgets() {
    set_par_min_flops(1);
    forall(
        "matmul/tr_matmul/gram/matvec serial == parallel",
        &Config { cases: 48, seed: 0xA11CE },
        dims(),
        |&(r, k, c)| {
            let mut rng = Pcg64::seeded((r * 1009 + k * 31 + c) as u64);
            let a = rand_mat(&mut rng, r, k);
            let b = rand_mat(&mut rng, k, c);
            let x: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
            let mm0 = pool::with_budget(1, || a.matmul(&b));
            let tm0 = pool::with_budget(1, || a.tr_matmul(&a));
            let g0 = pool::with_budget(1, || a.gram());
            let mv0 = pool::with_budget(1, || a.matvec(&x));
            let cs0 = pool::with_budget(1, || {
                let mut s = Vec::new();
                a.col_sums_into(&mut s);
                s
            });
            for &t in &BUDGETS {
                let mm = pool::with_budget(t, || a.matmul(&b));
                advgp::prop_assert!(mm.data == mm0.data, "matmul differs at budget {t}");
                let tm = pool::with_budget(t, || a.tr_matmul(&a));
                advgp::prop_assert!(tm.data == tm0.data, "tr_matmul differs at budget {t}");
                let g = pool::with_budget(t, || a.gram());
                advgp::prop_assert!(g.data == g0.data, "gram differs at budget {t}");
                let mv = pool::with_budget(t, || a.matvec(&x));
                advgp::prop_assert!(mv == mv0, "matvec differs at budget {t}");
                let cs = pool::with_budget(t, || {
                    let mut s = Vec::new();
                    a.col_sums_into(&mut s);
                    s
                });
                advgp::prop_assert!(cs == cs0, "col_sums differs at budget {t}");
            }
            Ok(())
        },
    );
}

#[test]
fn cross_bitwise_identical_across_budgets() {
    set_par_min_flops(1);
    forall(
        "kernel::cross serial == parallel",
        &Config { cases: 32, seed: 0xC0FFEE },
        dims(),
        |&(n, m, d)| {
            let mut rng = Pcg64::seeded((n * 131 + m * 17 + d) as u64);
            let p = ArdParams {
                log_a0: rng.normal() * 0.2,
                log_eta: (0..d).map(|_| rng.normal() * 0.3).collect(),
            };
            let x = rand_mat(&mut rng, n, d);
            let z = rand_mat(&mut rng, m, d);
            let k0 = pool::with_budget(1, || cross(&p, &x, &z));
            let kp0 = pool::with_budget(1, || cross_pairwise(&p, &x, &z));
            for &t in &BUDGETS {
                let k = pool::with_budget(t, || cross(&p, &x, &z));
                advgp::prop_assert!(k.data == k0.data, "cross differs at budget {t}");
                let kp = pool::with_budget(t, || cross_pairwise(&p, &x, &z));
                advgp::prop_assert!(
                    kp.data == kp0.data,
                    "cross_pairwise differs at budget {t}"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn phi_into_identical_across_budgets_and_reuse() {
    set_par_min_flops(1);
    let mut rng = Pcg64::seeded(99);
    let d = 3;
    let params = ArdParams { log_a0: 0.1, log_eta: vec![0.05, -0.1, 0.2] };
    let z = rand_mat(&mut rng, 9, d);
    let map = InducingChol::build(&params, z);
    let mut ws = PhiWorkspace::new();
    let mut out = PhiBatch::empty();
    for n in [0usize, 1, 5, 33, 130] {
        let x = rand_mat(&mut rng, n, d);
        let want = pool::with_budget(1, || map.phi(&params, &x));
        for &t in &BUDGETS {
            pool::with_budget(t, || map.phi_into(&params, &x, &mut ws, &mut out));
            assert_eq!(out.phi.data, want.phi.data, "phi n={n} budget={t}");
            assert_eq!(out.ktilde, want.ktilde, "ktilde n={n} budget={t}");
        }
    }
}

#[test]
fn native_grad_equivalent_across_budgets() {
    set_par_min_flops(1);
    let layout = ThetaLayout::new(6, 3);
    let mut rng = Pcg64::seeded(7);
    let z = rand_mat(&mut rng, 6, 3);
    let theta = Theta::init(layout, &z).data;
    // 17 chunks (CHUNK = 2048): the lane fan-out needs
    // `n_chunks >= 2 * budget`, so every budget in BUDGETS (max 8,
    // needing 16) takes the lane path on a sufficiently-parallel host.
    let n = 16 * 2048 + 137;
    let x = rand_mat(&mut rng, n, 3);
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut eng = NativeEngine::new(layout);
    let base = pool::with_budget(1, || eng.grad(&theta, &x, &y));
    for &t in &BUDGETS {
        let r = pool::with_budget(t, || eng.grad(&theta, &x, &y));
        let vscale = base.value.abs().max(1.0);
        assert!(
            (r.value - base.value).abs() < 1e-9 * vscale,
            "value differs at budget {t}: {} vs {}",
            r.value,
            base.value
        );
        for (i, (a, b)) in base.grad.iter().zip(&r.grad).enumerate() {
            assert!(
                (a - b).abs() < 1e-8 * a.abs().max(1.0) + 1e-9,
                "grad[{i}] differs at budget {t}: {a} vs {b}"
            );
        }
    }
    // And the empty shard edge case.
    let x0 = Mat::zeros(0, 3);
    let r0 = eng.grad(&theta, &x0, &[]);
    assert_eq!(r0.value, 0.0);
    assert!(r0.grad.iter().all(|g| g.abs() < 1e-12));
}

/// Per-row reference posterior (the pre-ISSUE-2 `SparseGp` loops): one
/// `u.matvec(φ_i)` per row, sequential sums.  The blocked path must
/// match it to ≤1e-12 elementwise at every thread budget.
fn reference_predict_and_data_term(
    gp: &SparseGp,
    x: &advgp::linalg::Mat,
    y: &[f64],
) -> (Vec<f64>, Vec<f64>, f64) {
    let theta = &gp.theta;
    let map = InducingChol::build(&theta.ard(), theta.z_mat());
    let pb = map.phi(&theta.ard(), x);
    let mu = theta.mu();
    let u = theta.u_mat();
    let mean = pb.phi.matvec(mu);
    let noise = (2.0 * theta.log_sigma()).exp();
    let beta = theta.beta();
    let log_sigma = theta.log_sigma();
    let mut var = Vec::with_capacity(x.rows);
    let mut g = 0.0;
    for i in 0..x.rows {
        let phi_i = pb.phi.row(i);
        let uphi = u.matvec(phi_i);
        let quad: f64 = uphi.iter().map(|v| v * v).sum();
        var.push((pb.ktilde[i] + quad).max(1e-12) + noise);
        let e = dot(phi_i, mu) - y[i];
        g += 0.5 * (2.0 * std::f64::consts::PI).ln() + log_sigma
            + 0.5 * beta * (e * e + quad + pb.ktilde[i]);
    }
    (mean, var, g)
}

fn random_sparse_gp(m: usize, d: usize, seed: u64) -> SparseGp {
    let mut rng = Pcg64::seeded(seed);
    let z = rand_mat(&mut rng, m, d);
    let mut th = Theta::init(ThetaLayout::new(m, d), &z);
    for v in th.mu_mut() {
        *v = rng.normal() * 0.5;
    }
    let mut u = Mat::zeros(m, m);
    for i in 0..m {
        u[(i, i)] = 0.5 + rng.next_f64();
        for j in i + 1..m {
            u[(i, j)] = rng.normal() * 0.1;
        }
    }
    th.set_u_mat(&u);
    th.data[th.layout.log_a0_idx()] = rng.normal() * 0.2;
    th.data[th.layout.log_sigma_idx()] = -0.5 + rng.normal() * 0.1;
    SparseGp::new(th)
}

/// ISSUE 2 tentpole invariant: the blocked, workspace-reusing
/// `predict_into`/`data_term_ws` match the per-row reference to ≤1e-12
/// elementwise across odd shapes and thread budgets 1–8, with pool
/// dispatch forced for every op.
#[test]
fn blocked_posterior_matches_per_row_reference_across_budgets() {
    set_par_min_flops(1);
    forall(
        "blocked predict/data_term == per-row reference",
        &Config { cases: 24, seed: 0x5E27E },
        |rng: &mut Pcg64| {
            const NS: [usize; 7] = [1, 2, 3, 7, 33, 65, 130];
            const MS: [usize; 4] = [1, 2, 5, 9];
            (
                NS[rng.next_below(NS.len() as u64) as usize],
                MS[rng.next_below(MS.len() as u64) as usize],
                1 + rng.next_below(3) as usize,
            )
        },
        |&(n, m, d)| {
            let mut rng = Pcg64::seeded((n * 7919 + m * 101 + d) as u64);
            let gp = random_sparse_gp(m, d, (n + m * 1000 + d) as u64);
            let x = rand_mat(&mut rng, n, d);
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let (mr, vr, gr) = reference_predict_and_data_term(&gp, &x, &y);
            let mut ws = PredictWorkspace::new();
            let mut mean = Vec::new();
            let mut var = Vec::new();
            for t in [1usize, 2, 3, 4, 8] {
                let g = pool::with_budget(t, || {
                    gp.predict_into(&x, &mut ws, &mut mean, &mut var);
                    gp.data_term_ws(&x, &y, &mut ws)
                });
                advgp::prop_assert!(mean == mr, "mean differs at budget {t} (n={n} m={m})");
                for i in 0..n {
                    let scale = vr[i].abs().max(1.0);
                    advgp::prop_assert!(
                        (var[i] - vr[i]).abs() <= 1e-12 * scale,
                        "var[{i}] {} vs {} at budget {t}",
                        var[i],
                        vr[i]
                    );
                }
                let gscale = gr.abs().max(1.0);
                advgp::prop_assert!(
                    (g - gr).abs() <= 1e-12 * gscale,
                    "data_term {g} vs {gr} at budget {t} (n={n} m={m} d={d})"
                );
            }
            Ok(())
        },
    );
}

/// `ADVGP_THREADS=1`-equivalent behaviour: budget 1 must bypass the
/// pool entirely and still satisfy every algebraic identity.
#[test]
fn budget_one_matches_reference_algebra() {
    set_par_min_flops(1);
    let mut rng = Pcg64::seeded(11);
    let a = rand_mat(&mut rng, 33, 17);
    let b = rand_mat(&mut rng, 17, 9);
    let got = pool::with_budget(1, || a.matmul(&b));
    // Naive triple loop reference.
    let mut want = Mat::zeros(33, 9);
    for i in 0..33 {
        for j in 0..9 {
            let mut s = 0.0;
            for k in 0..17 {
                s += a[(i, k)] * b[(k, j)];
            }
            want[(i, j)] = s;
        }
    }
    assert!(got.max_abs_diff(&want) < 1e-10);
}
