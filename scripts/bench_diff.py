#!/usr/bin/env python3
"""Diff two ADVGP bench JSON dumps and print a regression table.

Usage:
    scripts/bench_diff.py OLD.json NEW.json [--fail-over PCT]

Works on any file written by the `perf_hotpath` / `perf_predict`
benches (schema 1: {"benches": [{"name", "mean_ns", ...}]}; schema 2
adds an optional per-entry "backend").  Benches are matched on
(name, backend) — each compute backend's series is an independent row,
so a SIMD win never masks a scalar regression.  The table shows old/new
mean ns/iter and the relative delta (positive = slower).  Entries
present on only one side are listed separately.  Exit code is 0 unless
--fail-over is given and some bench regressed by more than PCT percent.

stdlib-only (the build environment is offline).
"""

import argparse
import json
import sys


def load(path):
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for b in doc.get("benches", []):
        name = b.get("name")
        mean = b.get("mean_ns")
        if name is not None and mean is not None:
            # Schema-1 files have no "backend"; "" keeps their keys
            # stable so old baselines still match the scalar rows of
            # benches that never grew a backend dimension.
            out[(name, b.get("backend", ""))] = b
    return doc, out


def display(key):
    name, backend = key
    # The benches embed "[backend]" in the name already; only append
    # when a file carries the field without the suffix.
    if backend and f"[{backend}]" not in name:
        return f"{name} [{backend}]"
    return name


def fmt_ns(ns):
    if ns < 1e3:
        return f"{ns:.0f}ns"
    if ns < 1e6:
        return f"{ns / 1e3:.2f}us"
    if ns < 1e9:
        return f"{ns / 1e6:.3f}ms"
    return f"{ns / 1e9:.3f}s"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old")
    ap.add_argument("new")
    ap.add_argument(
        "--fail-over",
        type=float,
        default=None,
        metavar="PCT",
        help="exit 1 if any bench regressed by more than PCT percent",
    )
    args = ap.parse_args()

    old_doc, old = load(args.old)
    new_doc, new = load(args.new)
    ot, nt = old_doc.get("threads"), new_doc.get("threads")
    if ot != nt:
        print(f"note: thread counts differ (old={ot}, new={nt}); deltas are not comparable\n")

    shared = [k for k in new if k in old]
    name_w = max((len(display(k)) for k in shared), default=4) + 2
    print(f"{'bench':<{name_w}} {'old':>10} {'new':>10} {'delta':>8}")
    worst = 0.0
    for key in shared:
        o, n = old[key]["mean_ns"], new[key]["mean_ns"]
        delta = (n - o) / o * 100.0 if o > 0 else float("nan")
        worst = max(worst, delta)
        flag = "  <-- regression" if delta > 10.0 else ""
        print(f"{display(key):<{name_w}} {fmt_ns(o):>10} {fmt_ns(n):>10} {delta:>+7.1f}%{flag}")
        rps_o, rps_n = old[key].get("rows_per_sec"), new[key].get("rows_per_sec")
        if rps_o and rps_n:
            print(f"{'':<{name_w}} {rps_o:>10.0f} {rps_n:>10.0f}  rows/s")

    for key in sorted(set(old) - set(new)):
        print(f"{display(key):<{name_w}} {fmt_ns(old[key]['mean_ns']):>10} {'(gone)':>10}")
    for key in sorted(set(new) - set(old)):
        print(f"{display(key):<{name_w}} {'(new)':>10} {fmt_ns(new[key]['mean_ns']):>10}")

    if args.fail_over is not None and worst > args.fail_over:
        print(f"\nFAIL: worst regression {worst:+.1f}% exceeds {args.fail_over}%")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
