//! Quickstart: train ADVGP on a small synthetic regression problem and
//! compare it against the exact O(n³) GP — the 60-second tour of the
//! public API.
//!
//!     cargo run --release --example quickstart

use advgp::data::{kmeans, synth, Standardizer};
use advgp::gp::exact::ExactGp;
use advgp::gp::{SparseGp, Theta, ThetaLayout};
use advgp::grad::native_factory;
use advgp::kernel::ArdParams;
use advgp::ps::coordinator::{native_eval_factory, train, TrainConfig};
use advgp::util::rng::Pcg64;
use advgp::util::{mnlp, rmse};

fn main() {
    // 1. Data: Friedman #1, 3000 train / 500 test, standardized.
    let mut ds = synth::friedman(3500, 4, 0.4, 0);
    let mut rng = Pcg64::seeded(0);
    ds.shuffle(&mut rng);
    let (mut train_ds, mut test_ds) = ds.split(500);
    let st = Standardizer::fit(&train_ds);
    st.apply(&mut train_ds);
    st.apply(&mut test_ds);

    // 2. Model: m = 20 inducing points from k-means (paper §6.3 init).
    let m = 20;
    let layout = ThetaLayout::new(m, train_ds.d());
    let z0 = kmeans::kmeans(&train_ds.x, m, 20, &mut rng);
    let theta0 = Theta::init(layout, &z0);

    // 3. Train: 4 asynchronous workers, delay limit τ = 8.
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 8;
    cfg.max_updates = 400;
    let res = train(
        &cfg,
        theta0.data.clone(),
        train_ds.shard(4),
        native_factory(layout),
        Some(native_eval_factory(layout, test_ds.clone(), None)),
    );
    println!(
        "trained {} updates in {:.2}s ({} gradient pushes, mean staleness {:.2})",
        res.stats.updates,
        res.wall_secs,
        res.stats.pushes,
        res.stats.staleness.mean()
    );

    // 4. Evaluate vs the exact GP (feasible at n=3000).
    let gp = SparseGp::new(Theta { layout, data: res.theta });
    let (mean, var) = gp.predict(&test_ds.x);
    let advgp_rmse = rmse(&mean, &test_ds.y);
    let advgp_mnlp = mnlp(&mean, &var, &test_ds.y);

    let exact = ExactGp::fit(
        ArdParams::unit(train_ds.d()),
        0.0,
        train_ds.x.clone(),
        &train_ds.y,
    );
    let (em, ev) = exact.predict(&test_ds.x);
    let exact_rmse = rmse(&em, &test_ds.y);
    let exact_mnlp = mnlp(&em, &ev, &test_ds.y);
    let mean_rmse = rmse(&vec![0.0; test_ds.n()], &test_ds.y);

    println!("\n{:<28}{:>10}{:>10}", "method", "RMSE", "MNLP");
    println!("{:<28}{:>10.4}{:>10.4}", "ADVGP (m=20, 4 workers)", advgp_rmse, advgp_mnlp);
    println!("{:<28}{:>10.4}{:>10.4}", "exact GP (n=3000)", exact_rmse, exact_mnlp);
    println!("{:<28}{:>10.4}{:>10}", "mean predictor", mean_rmse, "-");
    assert!(advgp_rmse < 0.7 * mean_rmse, "ADVGP should beat the mean handily");
    println!("\nquickstart OK");
}
