//! Distributed training over the ADVGPNT1 wire protocol (ISSUE 4) —
//! the whole parameter-server topology of `docs/PROTOCOL.md` in one
//! process, over real loopback TCP sockets:
//!
//!     cargo run --release --example net_train
//!
//! The walkthrough:
//! 1. partition a synthetic dataset into an on-disk shard store (what
//!    `advgp serve-ps --store` does);
//! 2. start the θ-server on an ephemeral loopback port
//!    ([`train_remote`] — the `advgp serve-ps` path);
//! 3. connect two remote workers ([`remote_worker_loop`] — the
//!    `advgp worker --connect` path), each streaming minibatch chunks
//!    from its shard file through the ADVGPSH1 reader;
//! 4. report the trace and the final test RMSE.
//!
//! For the true multi-process version of this run, see "Distributed
//! quickstart" in the README.

use advgp::data::store::ShardSet;
use advgp::data::{kmeans, synth, Standardizer};
use advgp::gp::{SparseGp, Theta, ThetaLayout};
use advgp::grad::native_factory;
use advgp::ps::coordinator::{native_eval_factory, train_remote, TrainConfig};
use advgp::ps::net::{remote_worker_loop, NetServer};
use advgp::ps::worker::{WorkerProfile, WorkerSource};
use advgp::util::rmse;
use advgp::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    // 1. Data → standardized splits → on-disk shard store.
    let mut ds = synth::friedman(4500, 4, 0.4, 0);
    let mut rng = Pcg64::seeded(0);
    ds.shuffle(&mut rng);
    let (mut train_ds, mut test_ds) = ds.split(500);
    let st = Standardizer::fit(&train_ds);
    st.apply(&mut train_ds);
    st.apply(&mut test_ds);

    let dir = std::env::temp_dir().join("advgp_example_net");
    let _ = std::fs::remove_dir_all(&dir);
    let store = ShardSet::create(&dir.join("store"), &train_ds, 2, 256)?;
    println!(
        "store: {} shards x ~{} rows (chunk 256) at {}",
        store.r(),
        store.n() / store.r(),
        store.dir().display()
    );

    let m = 16;
    let layout = ThetaLayout::new(m, train_ds.d());
    let z0 = kmeans::kmeans(&train_ds.x, m, 20, &mut rng);
    let theta0 = Theta::init(layout, &z0);

    // 2. Bind the server on an ephemeral port; workers learn it below.
    let net = NetServer::bind("127.0.0.1:0")?;
    let addr = net.local_addr().to_string();
    println!("server: ADVGPNT1 on {addr}");

    // 3. Two remote workers (threads here; separate `advgp worker`
    //    processes in a real deployment — same wire traffic either way).
    let workers: Vec<_> = (0..store.r())
        .map(|k| {
            let addr = addr.clone();
            let reader = store.reader(k)?;
            Ok(std::thread::spawn(move || {
                remote_worker_loop(
                    &addr,
                    Some(k),
                    WorkerSource::Store(reader),
                    native_factory(layout),
                    WorkerProfile::default(),
                )
                .expect("remote worker failed")
            }))
        })
        .collect::<anyhow::Result<_>>()?;

    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 8;
    cfg.max_updates = 300;
    cfg.eval_every_secs = 0.05;
    let res = train_remote(
        &cfg,
        theta0.data.clone(),
        net,
        store.r(),
        Some(native_eval_factory(layout, test_ds.clone(), None)),
    );
    for w in workers {
        w.join().expect("worker thread panicked");
    }

    // 4. Results.
    println!(
        "run: {} updates, {} pushes, staleness p95 ≈ {:.1}, wall {:.2}s",
        res.stats.updates,
        res.stats.pushes,
        res.stats.staleness.quantile(0.95),
        res.wall_secs
    );
    if let (Some(first), Some(last)) = (res.trace.first(), res.trace.last()) {
        println!(
            "trace: rmse {:.4} (v{}) → {:.4} (v{})",
            first.rmse, first.version, last.rmse, last.version
        );
    }
    let gp = SparseGp::new(Theta { layout, data: res.theta });
    let (mean, _) = gp.predict(&test_ds.x);
    let final_rmse = rmse(&mean, &test_ds.y);
    let baseline = rmse(&vec![0.0; test_ds.n()], &test_ds.y);
    println!("final test RMSE {final_rmse:.4} (mean predictor {baseline:.4})");
    anyhow::ensure!(final_rmse < baseline, "networked training must beat the mean");
    println!("OK: distributed loopback run converged");
    Ok(())
}
