//! Training at scale (ISSUE 3): out-of-core shard store, mid-run worker
//! departure, durable checkpoints, and an exact resume.
//!
//!     cargo run --release --example checkpoint_resume
//!
//! The walkthrough:
//! 1. partition a synthetic dataset to an on-disk [`ShardSet`] — each
//!    worker will stream minibatch chunks from its shard file instead
//!    of holding a resident clone;
//! 2. train with `checkpoint_every` set, while one worker *leaves*
//!    mid-run (the bounded-staleness gate retires its clock and the run
//!    proceeds) and a late joiner adopts the live θ;
//! 3. "crash" (stop), then resume from the newest checkpoint: the first
//!    θ the resumed run publishes is bitwise the checkpointed θ.

use advgp::data::store::ShardSet;
use advgp::data::{kmeans, synth, Dataset, Standardizer};
use advgp::gp::{SparseGp, Theta, ThetaLayout};
use advgp::grad::native_factory;
use advgp::linalg::Mat;
use advgp::ps::coordinator::{
    native_eval_factory, train_elastic, train_sources, Joiner, TrainConfig,
};
use advgp::ps::worker::{WorkerProfile, WorkerSource};
use advgp::ps::{Checkpoint, Published};
use advgp::util::rng::Pcg64;
use advgp::util::rmse;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // 1. Data → disk.  4000 train / 500 test, standardized, then
    //    partitioned once into 3 shard files + manifest.
    let mut ds = synth::friedman(4500, 4, 0.4, 0);
    let mut rng = Pcg64::seeded(0);
    ds.shuffle(&mut rng);
    let (mut train_ds, mut test_ds) = ds.split(500);
    let st = Standardizer::fit(&train_ds);
    st.apply(&mut train_ds);
    st.apply(&mut test_ds);

    let dir = std::env::temp_dir().join("advgp_example_ck");
    let _ = std::fs::remove_dir_all(&dir);
    let store = ShardSet::create(&dir.join("store"), &train_ds, 3, 256)?;
    let ck_dir = dir.join("checkpoints");
    println!(
        "store: {} shards x ~{} rows (chunk 256) at {}",
        store.r(),
        store.n() / store.r(),
        store.dir().display()
    );

    let m = 16;
    let layout = ThetaLayout::new(m, train_ds.d());
    let z0 = kmeans::kmeans(&train_ds.x, m, 20, &mut rng);
    let theta0 = Theta::init(layout, &z0);

    // 2. First leg: 150 updates, checkpoint every 25, worker 2 leaves
    //    at its 10th iteration, and a 4th worker joins after 10 ms.
    let mut cfg = TrainConfig::new(layout);
    cfg.tau = 8;
    cfg.max_updates = 150;
    cfg.eval_every_secs = 0.05;
    cfg.checkpoint_every = 25;
    cfg.checkpoint_dir = Some(ck_dir.clone());
    cfg.profiles = vec![
        WorkerProfile::default(),
        WorkerProfile::default(),
        WorkerProfile { leave_at: Some(10), ..Default::default() },
    ];
    let sources: Vec<WorkerSource> =
        store.readers()?.into_iter().map(WorkerSource::Store).collect();
    let joiner_shard = {
        // The joiner re-reads worker 0's shard — in a real deployment a
        // joiner opens whatever shard the scheduler hands it.
        let mut r = store.reader(0)?;
        r.set_chunk_rows(256);
        WorkerSource::Store(r)
    };
    let res1 = train_elastic(
        &cfg,
        Published::new(theta0.data.clone()),
        sources,
        vec![Joiner {
            after: Duration::from_millis(10),
            source: joiner_shard,
            profile: WorkerProfile::default(),
        }],
        native_factory(layout),
        Some(native_eval_factory(layout, test_ds.clone(), None)),
    );
    println!(
        "leg 1: {} updates, {} pushes, joins={} leaves={} (the gate retired \
         the leaver and the run kept going)",
        res1.stats.updates, res1.stats.pushes, res1.stats.joins, res1.stats.leaves
    );
    assert!(res1.stats.leaves >= 1, "worker 2 should have departed");

    // 3. Resume from the newest checkpoint and finish the run.
    let ck = Checkpoint::load_latest(&ck_dir)?.expect("checkpoints written");
    println!("resuming from version {} ({})", ck.version, ck_dir.display());
    let resumed_version = ck.version;
    let mut cfg2 = TrainConfig::new(layout);
    cfg2.tau = 8;
    cfg2.max_updates = 300; // cumulative ceiling: continues 150 → 300
    cfg2.eval_every_secs = 0.05;
    cfg2.checkpoint_every = 25;
    cfg2.checkpoint_dir = Some(ck_dir.clone());
    cfg2.resume_from = Some(ck);
    let sources2: Vec<WorkerSource> =
        store.readers()?.into_iter().map(WorkerSource::Store).collect();
    let res2 = train_sources(
        &cfg2,
        theta0.data.clone(), // ignored: the checkpoint wins
        sources2,
        native_factory(layout),
        Some(native_eval_factory(layout, test_ds.clone(), None)),
    );
    let first = res2.trace.first().expect("trace recorded");
    // The trace continues from the checkpoint (the evaluator may catch
    // the seeded version itself or the first few updates after it —
    // never anything older).  The bitwise θ guarantee is pinned
    // race-free in `rust/tests/store_checkpoint.rs`.
    assert!(first.version >= resumed_version, "trace must continue at ck");
    println!(
        "leg 2: resumed at v{} and reached v{} in {:.2}s",
        resumed_version, res2.stats.updates, res2.wall_secs
    );
    // Leg 2 kept checkpointing past the resume point.
    let again = Checkpoint::load_latest(&ck_dir)?.unwrap();
    assert!(again.version > resumed_version, "leg 2 advanced the checkpoint");

    // 4. Final quality check on the resumed model.
    let gp = SparseGp::new(Theta { layout, data: res2.theta.clone() });
    let (mean, _) = gp.predict(&test_ds.x);
    let final_rmse = rmse(&mean, &test_ds.y);
    let base = rmse(&vec![0.0; test_ds.n()], &test_ds.y);
    println!("final RMSE {final_rmse:.4} vs mean predictor {base:.4}");
    assert!(final_rmse < 0.7 * base, "resumed model should beat the mean");

    // Windows stream through one reusable buffer; show the store reader
    // profile once for the curious.
    let mut probe = store.reader(0)?;
    let mut win = Dataset { x: Mat::empty(), y: Vec::new() };
    probe.next_window(&mut win)?;
    let cap = probe.buf_capacity();
    for _ in 0..64 {
        probe.next_window(&mut win)?;
    }
    assert_eq!(probe.buf_capacity(), cap, "steady-state reads allocate nothing");
    println!("\ncheckpoint_resume OK");
    Ok(())
}
