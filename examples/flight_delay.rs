//! Flight-delay regression (the paper's §6.1 workload, DESIGN.md §4
//! substitution): ADVGP vs SVIGP vs DistGP-GD on the flight-like
//! generator, reporting RMSE in delay minutes.
//!
//!     cargo run --release --example flight_delay -- \
//!         [--n 40000] [--m 100] [--budget 12] [--workers 4] [--tau 32]

use advgp::experiments::methods::*;
use advgp::experiments::{flight_problem, print_table};
use advgp::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("n", 40_000);
    let m = args.usize_or("m", 100);
    let budget = args.f64_or("budget", 12.0);
    let workers = args.usize_or("workers", 4);
    let tau = args.u64_or("tau", 32);

    println!("flight-like: n={n} (test 5000), m={m}, {workers} workers, τ={tau}, budget {budget}s");
    let p = flight_problem(n, 5_000, m, 1);
    let y_std = p.standardizer.y_std;

    let opts = MethodOpts { budget_secs: budget, tau, workers, ..Default::default() };
    let sync = MethodOpts { budget_secs: budget, tau: 0, workers, ..Default::default() };
    let advgp = run_advgp(&p, &opts);
    let svigp = run_svigp_method(&p, &opts);
    let gd = run_distgp_gd_method(&p, &sync);

    let rows = vec![
        vec!["ADVGP".into(),
             format!("{:.4}", final_rmse(&advgp) * y_std),
             format!("{:.4}", final_mnlp(&advgp)),
             format!("{}", advgp.trace.last().map(|t| t.version).unwrap_or(0))],
        vec!["SVIGP".into(),
             format!("{:.4}", final_rmse(&svigp) * y_std),
             format!("{:.4}", final_mnlp(&svigp)),
             format!("{}", svigp.trace.last().map(|t| t.version).unwrap_or(0))],
        vec!["DistGP-GD".into(),
             format!("{:.4}", final_rmse(&gd) * y_std),
             format!("{:.4}", final_mnlp(&gd)),
             format!("{}", gd.trace.last().map(|t| t.version).unwrap_or(0))],
    ];
    print_table(
        "flight delay prediction (RMSE in minutes)",
        &["Method", "RMSE", "MNLP", "iterations"],
        &rows,
    );
}
