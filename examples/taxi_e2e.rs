//! END-TO-END VALIDATION DRIVER (recorded in EXPERIMENTS.md).
//!
//! The full three-layer stack on the paper's §6.3 workload shape:
//! a taxi-like travel-time dataset streamed through the **AOT
//! JAX+Pallas artifacts via PJRT** (L1+L2) under the **asynchronous
//! parameter server** (L3) — Python never runs.  Compares against the
//! VW-style linear baseline and the mean predictor, logs the
//! RMSE-vs-time curve, and asserts the paper's qualitative result
//! (GP ≫ linear ≫ mean).
//!
//!     make artifacts   # once
//!     cargo run --release --example taxi_e2e -- \
//!         [--n 300000] [--workers 8] [--tau 20] [--budget 60] [--engine xla|native]

use advgp::experiments::methods::*;
use advgp::experiments::{out_dir, print_table, taxi_problem};
use advgp::ps::metrics::write_trace_csv;
use advgp::runtime::{engine::xla_factory, Manifest};
use advgp::util::cli::Args;
use std::path::Path;

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("n", 300_000);
    let n_test = args.usize_or("n-test", 20_000);
    let workers = args.usize_or("workers", 8);
    let tau = args.u64_or("tau", 20);
    let budget = args.f64_or("budget", 120.0);
    let engine = args.str_or("engine", "xla").to_string();
    let m = 50;

    println!("taxi e2e: n={n}/{n_test}, m={m}, {workers} workers, τ={tau}, budget {budget}s, engine={engine}");
    println!("building problem (k-means init per paper §6.3)…");
    let p = taxi_problem(n, n_test, m, 2024);
    let y_std = p.standardizer.y_std;
    println!(
        "θ has {} parameters; mean travel time {:.0}s, std {:.0}s",
        p.layout.len(),
        p.standardizer.y_mean,
        y_std
    );

    let opts = MethodOpts {
        budget_secs: budget,
        tau,
        workers,
        eval_every_secs: 1.0,
        ..Default::default()
    };

    // L1+L2 through PJRT when artifacts exist (the production path).
    let advgp = if engine == "xla" {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        match Manifest::load(&dir) {
            Ok(man) if man.find(advgp::runtime::ArtifactKind::Grad, m, 9).is_ok() => {
                println!("using XLA engine (AOT JAX+Pallas artifacts)");
                run_advgp_with(&p, &opts, xla_factory(man, m, 9))
            }
            _ => {
                eprintln!("WARNING: artifacts missing; falling back to native engine");
                run_advgp(&p, &opts)
            }
        }
    } else {
        println!("using native engine");
        run_advgp(&p, &opts)
    };

    println!("training done: {} server updates in {:.1}s",
             advgp.trace.last().map(|t| t.version).unwrap_or(0), advgp.wall_secs);
    let linear = run_linear_method(&p, &opts);
    let mean = run_mean_method(&p);

    let dir = out_dir().join("taxi_e2e");
    write_trace_csv(&dir.join("advgp.csv"), &advgp.trace).unwrap();
    write_trace_csv(&dir.join("linear.csv"), &linear.trace).unwrap();
    println!("RMSE-vs-time traces -> {}", dir.display());

    let gp = final_rmse(&advgp) * y_std;
    let lin = final_rmse(&linear) * y_std;
    let mn = final_rmse(&mean) * y_std;
    print_table(
        "taxi travel-time prediction (RMSE, seconds)",
        &["Method", "RMSE (s)", "vs ADVGP"],
        &[
            vec!["ADVGP".into(), format!("{gp:.1}"), "-".into()],
            vec!["linear (VW-style)".into(), format!("{lin:.1}"),
                 format!("GP better by {:.1}%", 100.0 * (1.0 - gp / lin))],
            vec!["mean prediction".into(), format!("{mn:.1}"),
                 format!("GP better by {:.1}%", 100.0 * (1.0 - gp / mn))],
        ],
    );

    // The paper's §6.3 findings, asserted:
    assert!(gp < lin, "GP must beat the linear model");
    assert!(lin < mn, "linear must beat the mean");
    println!("\ntaxi_e2e OK (paper-shape assertions hold: GP < linear < mean)");
}
