//! Delay-limit tuning demo (the paper's §6.1 τ-selection procedure and
//! Fig. 2 in miniature): sweep τ with injected stragglers and report
//! final RMSE + server throughput, showing the sync-slow / moderate-τ-
//! best / huge-τ-degrades curve.
//!
//!     cargo run --release --example delay_tuning -- \
//!         [--n 20000] [--budget 6] [--taus 0,5,10,20,40,80,160]

use advgp::experiments::methods::*;
use advgp::experiments::{flight_problem, print_table};
use advgp::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("n", 20_000);
    let budget = args.f64_or("budget", 6.0);
    let taus = args.usize_list_or("taus", &[0, 5, 10, 20, 40, 80, 160]);

    let p = flight_problem(n, 4_000, 50, 3);
    let y_std = p.standardizer.y_std;
    let mut rows = Vec::new();
    let mut best = (f64::INFINITY, 0usize);
    for &tau in &taus {
        let opts = MethodOpts {
            budget_secs: budget,
            tau: tau as u64,
            workers: 6,
            straggle_ms: vec![0, 0, 10, 10, 20, 20],
            ..Default::default()
        };
        let r = run_advgp(&p, &opts);
        let rmse = final_rmse(&r) * y_std;
        let updates = r.trace.last().map(|t| t.version).unwrap_or(0);
        if rmse < best.0 {
            best = (rmse, tau);
        }
        rows.push(vec![
            format!("{tau}"),
            format!("{rmse:.4}"),
            format!("{updates}"),
            format!("{:.1}", updates as f64 / budget),
        ]);
    }
    print_table(
        &format!("delay-limit sweep (budget {budget}s, stragglers 0/10/20ms)"),
        &["τ", "RMSE (min)", "updates", "updates/s"],
        &rows,
    );
    println!("\nbest τ = {} (RMSE {:.4}) — the paper picked τ=32 for its cluster", best.1, best.0);
}
