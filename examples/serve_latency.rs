//! Serve a synthetic query stream against the **live published θ** of
//! an in-flight training run — the serving half of the system (ISSUE 2).
//!
//!     cargo run --release --example serve_latency
//!
//! Topology: the parameter server trains on a background thread via
//! `train_published` (so we own the `Published` handle); a
//! `serve::BatchServer` follows it through a `PosteriorCache` (one
//! O(m³) posterior rebuild per θ version, atomically swapped); client
//! threads fire single-row predict requests the whole time.  At the end
//! we print rows/sec, latency percentiles, and the span of θ versions
//! that actually served traffic.

use advgp::data::{kmeans, synth, Standardizer};
use advgp::gp::{Theta, ThetaLayout};
use advgp::grad::native_factory;
use advgp::ps::coordinator::{train_published, TrainConfig};
use advgp::ps::Published;
use advgp::serve::{BatchConfig, BatchServer, PosteriorCache};
use advgp::util::rng::Pcg64;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    // 1. Data: flight-like synthetic, 12k train / 2k query pool.
    let mut ds = synth::flight_like(14_000, 5);
    let mut rng = Pcg64::seeded(5);
    ds.shuffle(&mut rng);
    let (mut train_ds, mut query_ds) = ds.split(2_000);
    let st = Standardizer::fit(&train_ds);
    st.apply(&mut train_ds);
    st.apply(&mut query_ds);
    let d = train_ds.d();

    // 2. Model: m = 64 inducing points, k-means init.
    let m = 64;
    let layout = ThetaLayout::new(m, d);
    let z0 = kmeans::kmeans(&train_ds.x, m, 10, &mut rng);
    let theta0 = Theta::init(layout, &z0);

    // 3. Trainer on a background thread, publishing into a handle we own.
    let published = Published::new(theta0.data.clone());
    let trainer = {
        let published = Arc::clone(&published);
        let shards = train_ds.shard(4);
        std::thread::spawn(move || {
            let mut cfg = TrainConfig::new(layout);
            cfg.tau = 16;
            cfg.max_updates = 400;
            cfg.eval_every_secs = 0.0;
            train_published(&cfg, published, shards, native_factory(layout), None)
        })
    };

    // 4. Batch server following the live θ.
    let cache = Arc::new(PosteriorCache::new(layout));
    let cfg = BatchConfig { max_rows: 256, latency_budget: Duration::from_millis(1) };
    let (server, client) =
        BatchServer::start(Arc::clone(&cache), Some(Arc::clone(&published)), cfg);

    // 5. Query stream: 4 clients hammer the server until training ends.
    let stop = Arc::new(AtomicBool::new(false));
    let clients: Vec<_> = (0..4)
        .map(|c| {
            let client = client.clone();
            let stop = Arc::clone(&stop);
            let queries = query_ds.clone();
            std::thread::spawn(move || {
                let n = queries.n();
                let mut i = c * (n / 4);
                let mut served = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let row = queries.x.row(i % n);
                    if client.predict(row).is_none() {
                        break; // server gone
                    }
                    served += 1;
                    i += 1;
                }
                served
            })
        })
        .collect();
    drop(client);

    let run = trainer.join().expect("trainer panicked");
    stop.store(true, Ordering::Relaxed);
    let served: u64 = clients.into_iter().map(|h| h.join().unwrap()).sum();
    let report = server.join();

    // 6. Report.
    println!(
        "training: {} updates in {:.2}s ({} pushes, mean staleness {:.2})",
        run.stats.updates, run.wall_secs, run.stats.pushes, run.stats.staleness.mean()
    );
    println!("serving:  {}", report.summary());
    println!(
        "          client-side confirmed rows: {served}; posterior followed θ v{} → v{}",
        report.first_version, report.last_version
    );
    assert_eq!(report.rows, served);
    assert!(
        report.last_version > report.first_version,
        "server should have observed θ advancing while serving"
    );
}
